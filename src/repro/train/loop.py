"""Training loop: grad-accumulation microbatching, jitted step builder,
gradient compression hook (pod-axis), deterministic metrics."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import optimizer as opt_mod


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0

    def as_pytree(self):
        return {"params": self.params, "opt_state": self.opt_state}


def make_train_step(
    loss_fn: Callable,
    opt_cfg: opt_mod.OptimizerConfig,
    *,
    grad_accum: int = 1,
    compress_fn: Optional[Callable] = None,
):
    """Build ``step(state_pytree, batch) -> (state_pytree, metrics)``.

    ``grad_accum`` > 1 expects batch leaves shaped [accum, ...] and scans
    microbatches, accumulating f32 grads (memory = one param-sized buffer).
    ``compress_fn`` (runtime.compression) maps grads -> grads before the
    optimizer, modelling the pod-axis compressed all-reduce.
    """

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        return loss, grads

    def step(state, batch):
        params = state["params"]
        if grad_accum > 1:
            def body(acc, mb):
                loss, grads = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum, acc, grads
                )
                return acc, loss

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, losses = jax.lax.scan(body, zero, batch)
            loss = losses.mean()
        else:
            loss, grads = grads_of(params, batch)
        if compress_fn is not None:
            grads = compress_fn(grads)
        new_params, new_opt, om = opt_mod.update(
            grads, state["opt_state"], params, opt_cfg
        )
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt_state": new_opt}, metrics

    return step


def init_state(params, opt_cfg: opt_mod.OptimizerConfig) -> dict:
    return {"params": params, "opt_state": opt_mod.init(params, opt_cfg)}


def train(
    state: dict,
    step_fn: Callable,
    batches,
    *,
    hooks=(),
    log_every: int = 10,
) -> tuple[dict, list[dict]]:
    """Simple driver: iterate batches, run hooks (checkpoint/fault)."""
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    history = []
    t0 = time.time()
    for i, batch in enumerate(batches):
        state, metrics = jitted(state, batch)
        for h in hooks:
            state = h(i, state) or state
        if i % log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall"] = time.time() - t0
            history.append(m)
    return state, history
