"""Optimizers: AdamW (configurable state dtypes — bf16 m/v for the 480B
MoE to fit single-pod HBM) and Adafactor (factored second moment), plus
global-norm clipping and warmup-cosine schedule.  Pure-pytree API."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 halves optimizer HBM (arctic)
    min_lr_ratio: float = 0.1


def schedule(step, cfg: OptimizerConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def init(params, cfg: OptimizerConfig):
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "adafactor":
        def factored(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], cfg.state_dtype),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.state_dtype),
                }
            return {"v": jnp.zeros(p.shape, cfg.state_dtype)}

        return {
            "f": jax.tree.map(factored, params, is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "sgd":
        return {"step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def update(grads, state, params, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(
                cfg.state_dtype
            ),
            state["m"],
            grads,
        )
        v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * g * g).astype(
                cfg.state_dtype
            ),
            state["v"],
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            d = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                d = d + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, {"lr": lr, "grad_norm": gn}

    if cfg.name == "adafactor":
        eps = 1e-30

        def upd(p, g, f):
            g32 = g * g + eps
            if p.ndim >= 2:
                vr = 0.95 * f["vr"].astype(jnp.float32) + 0.05 * g32.mean(-1)
                vc = 0.95 * f["vc"].astype(jnp.float32) + 0.05 * g32.mean(-2)
                denom = (
                    vr[..., :, None]
                    * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1)[..., None, None], eps)
                )
                d = g / jnp.sqrt(denom + eps)
                nf = {"vr": vr.astype(cfg.state_dtype), "vc": vc.astype(cfg.state_dtype)}
            else:
                v = 0.95 * f["v"].astype(jnp.float32) + 0.05 * g32
                d = g / jnp.sqrt(v + eps)
                nf = {"v": v.astype(cfg.state_dtype)}
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), nf

        flat_p, tp = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_f = state["f"]
        flat_f_l = jax.tree.leaves(flat_f, is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x))
        outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f_l)]
        new_params = jax.tree.unflatten(tp, [o[0] for o in outs])
        new_f = jax.tree.unflatten(
            jax.tree.structure(flat_f, is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)),
            [o[1] for o in outs],
        )
        return new_params, {"f": new_f, "step": step}, {"lr": lr, "grad_norm": gn}

    if cfg.name == "sgd":
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params,
            grads,
        )
        return new_params, {"step": step}, {"lr": lr, "grad_norm": gn}
    raise ValueError(cfg.name)
