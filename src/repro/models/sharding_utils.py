"""Activation sharding constraints.

GSPMD propagates operand shardings, but a gather from a vocab/row-sharded
table (embedding lookups) and segment scatters produce *replicated*
outputs — without explicit constraints every downstream activation
replicates and per-device memory explodes (measured: 55 GiB/dev for one
mistral-large layer, §Perf iteration 1).  Models therefore carry optional
axis names in their configs and pin activations at layer boundaries.

No-ops when the config carries no axes (CPU smoke tests) — constraints
only activate under the dry-run's `jax.set_mesh` context.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def constrain(x, *spec_parts):
    """with_sharding_constraint with bare PartitionSpec parts.

    ``spec_parts`` shorter than x.ndim are right-padded with None.  Any
    falsy part (None, "", ()) means replicated on that dim.  Axes that do
    not divide the dimension are dropped (divisibility guard, mirroring
    launch.shardings.tree_spec) — and with no ambient mesh the call is a
    no-op, so model code is safe to run un-meshed.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    parts = [p if p else None for p in spec_parts]
    parts += [None] * (x.ndim - len(parts))
    fixed = []
    for dim, part in enumerate(parts[: x.ndim]):
        if part is None:
            fixed.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        fixed.append(part if x.shape[dim] % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def maybe_constrain(x, axes, *rest):
    """Constrain dim0 to ``axes`` (tuple of mesh axis names) when given."""
    if not axes:
        return x
    return constrain(x, tuple(axes), *rest)
