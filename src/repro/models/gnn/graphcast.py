"""GraphCast (arXiv:2212.12794): encoder-processor-decoder mesh GNN.

Assigned-shape adaptation (DESIGN.md §4): the benchmark shapes provide a
single graph, so grid2mesh/mesh2grid bipartite graphs collapse onto it —
encoder/decoder become per-node MLPs (227 vars ↔ 512 latent) and the
processor is the full 16-layer interaction network over the mesh edges
(edge MLP on [e, h_src, h_dst] → sum-aggregate → node MLP, residual),
which is where GraphCast's compute lives.  mesh_refinement=6 sizes the
production icosahedral mesh in configs/graphcast.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common
from .. import sharding_utils as su


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    aggregator: str = "sum"
    shard_axes: tuple = ()   # mesh axes for node/edge dim-0 sharding
    remat: bool = False      # checkpoint each processor layer (large graphs)
    bf16: bool = False       # bf16 edge/node latents (halves residual HBM)


def init_params(key, cfg: GraphCastConfig):
    d = cfg.d_hidden
    keys = jax.random.split(key, 3 + 2 * cfg.n_layers)
    params = {
        "encoder": common.init_mlp(keys[0], [cfg.n_vars, d, d]),
        "edge_embed": common.init_mlp(keys[1], [4, d, d]),  # (rel dist feats)
        "decoder": common.init_mlp(keys[2], [d, d, cfg.n_vars]),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        params["layers"].append(
            {
                "edge_mlp": common.init_mlp(keys[3 + 2 * i], [3 * d, d, d]),
                "node_mlp": common.init_mlp(keys[4 + 2 * i], [2 * d, d, d]),
            }
        )
    return params


def forward(params, g: dict, cfg: GraphCastConfig):
    """g: {node_feat [N, n_vars], edge_src, edge_dst} -> next-state [N, n_vars]."""
    x = g["node_feat"].astype(jnp.float32)
    src, dst = g["edge_src"], g["edge_dst"]
    n = x.shape[0]
    cd = jnp.bfloat16 if cfg.bf16 else jnp.float32
    params = jax.tree.map(lambda p: p.astype(cd), params)
    h = common.mlp(params["encoder"], x.astype(cd))
    # structural edge features: degree-ish placeholders when no positions
    if g.get("positions") is not None:
        pos = g["positions"].astype(jnp.float32)
        rel = common.gather(pos, src) - common.gather(pos, dst)
        r = jnp.sqrt(jnp.sum(rel * rel, -1, keepdims=True) + 1e-12)
        ef = jnp.concatenate([rel, r], axis=-1).astype(cd)
    else:
        ef = jnp.zeros((src.shape[0], 4), cd)
    e = su.maybe_constrain(common.mlp(params["edge_embed"], ef), cfg.shard_axes)
    # N ≪ E regime: node latents REPLICATED (explicitly — otherwise GSPMD
    # all-gathers h per edge-gather and dozens of full copies stay live,
    # measured 315 GiB/dev), edge tensors sharded over all axes; the
    # aggregate becomes one all-reduce of [N, d] per layer (§Perf iter 4).
    if cfg.shard_axes:
        h = su.constrain(h)  # replicated

    def layer(lp, e, h):
        hs = common.gather(h, src)
        hd = common.gather(h, dst)
        e = e + common.mlp(lp["edge_mlp"], jnp.concatenate([e, hs, hd], -1))
        e = su.maybe_constrain(e, cfg.shard_axes)
        agg = common.aggregate(e, dst, n, mode=cfg.aggregator)
        if cfg.shard_axes:
            agg = su.constrain(agg)  # all-reduce partial node sums
        h = h + common.mlp(lp["node_mlp"], jnp.concatenate([h, agg], -1))
        return e, h

    if cfg.remat:  # §Perf: recompute processor activations in the backward
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    for lp in params["layers"]:
        e, h = layer(lp, e, h)
    return x + common.mlp(params["decoder"], h).astype(jnp.float32)  # residual


def loss_fn(params, g: dict, cfg: GraphCastConfig):
    pred = forward(params, g, cfg)
    target = g["labels"].astype(jnp.float32)
    mse = jnp.mean((pred - target) ** 2)
    return mse, {"mse": mse}
