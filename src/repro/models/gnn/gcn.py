"""GCN (Kipf & Welling, arXiv:1609.02907) — gcn-cora config.

Ĥ = σ( D̃^{-1/2} Ã D̃^{-1/2} H W ) via gather + segment_sum; the SpMM is
exactly the paper's traversal primitive, so the dynamic-update benchmarks
run GCN forward passes on updated graphs (paper §4.2.5 analogue).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common
from .. import sharding_utils as su


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    n_classes: int = 7
    aggregator: str = "mean"
    norm: str = "sym"
    dropout: float = 0.5
    shard_axes: tuple = ()   # mesh axes for node/edge dim-0 sharding


def init_params(key, cfg: GCNConfig):
    sizes = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    layers = []
    for i in range(cfg.n_layers):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), jnp.float32) / (
            sizes[i] ** 0.5
        )
        layers.append({"w": w, "b": jnp.zeros((sizes[i + 1],), jnp.float32)})
    return {"layers": layers}


def forward(params, g: dict, cfg: GCNConfig):
    """g: {node_feat [N,F], edge_src [E], edge_dst [E]} (+self-loops added)."""
    x = g["node_feat"].astype(jnp.float32)
    n = x.shape[0]
    src, dst = g["edge_src"], g["edge_dst"]
    deg = jax.ops.segment_sum(
        jnp.ones(src.shape[0], jnp.float32), jnp.minimum(dst, n), num_segments=n + 1
    )[:n] + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    x = su.maybe_constrain(x, cfg.shard_axes)
    for i, lp in enumerate(params["layers"]):
        h = x @ lp["w"] + lp["b"]
        if cfg.norm == "sym":
            msg = common.gather(h * inv_sqrt[:, None], src)
            agg = common.aggregate(msg, dst, n) * inv_sqrt[:, None]
            agg = agg + h / deg[:, None]  # self loop
        else:
            msg = common.gather(h, src)
            agg = common.aggregate(msg, dst, n, mode=cfg.aggregator) + h
        x = jax.nn.relu(agg) if i < len(params["layers"]) - 1 else agg
        x = su.maybe_constrain(x, cfg.shard_axes)
    return x


def loss_fn(params, g: dict, cfg: GCNConfig):
    logits = forward(params, g, cfg)
    labels = g["labels"]
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[:, None], axis=-1
    )[:, 0]
    ce = jnp.where(mask, lse - gold, 0.0).sum() / jnp.maximum(mask.sum(), 1)
    return ce, {"ce": ce}
