"""MACE (arXiv:2206.07697): higher-order E(3)-equivariant message passing,
l_max=2, correlation order 3 — in **Cartesian tensor form**.

Hardware adaptation (DESIGN.md §2): spherical-harmonic irrep bookkeeping
(l,m) indexing + CG tables) maps poorly onto the MXU; for l_max ≤ 2 the
irreps are exactly {scalar, vector, traceless-symmetric matrix}, and every
Clebsch-Gordan coupling is a classical vector/tensor product:

    0⊗0→0: s·s      1⊗1→0: v·v        2⊗2→0: T:T
    0⊗1→1: s·v      1⊗1→1: v×v        2⊗1→1: T·v
    1⊗1→2: sym₀(v⊗v)  0⊗2→2: s·T      2⊗2→2: sym₀(T·T)

All are einsums → MXU-friendly, equivariant by construction.  The ACE
density A is built per edge from radial (Bessel) × angular (r̂ tensors) ×
neighbor features; the product basis B applies the coupling table
recursively to correlation order 3; readout takes invariant (scalar)
channels.  Energy is extensive (sum of site energies); forces come from
jax.grad and are equivariant by composition (tested).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common
from .. import sharding_utils as su


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128          # channels per irrep
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 100
    # edge-chunked density aggregation: bounds the per-edge rank-2 tensor
    # working set to chunk·C·9 floats (needed for 10⁷–10⁸-edge graphs);
    # 0 = unchunked.  The aggregation is linear in edges, so chunking is
    # exact — it is remat over the edge axis.
    edge_chunks: int = 0
    shard_axes: tuple = ()   # mesh axes for node/edge dim-0 sharding


# --- Cartesian irrep algebra -------------------------------------------------
def sym0(t):
    """Symmetric traceless part of [..., 3, 3]."""
    s = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * jnp.eye(3) / 3.0


def pairwise_product(a, b, w):
    """All CG couplings of feature dicts a,b -> feature dict.

    a,b: {"s":[...,C], "v":[...,C,3], "t":[...,C,3,3]}; w: per-path
    per-channel weights {"path_name": [C]}.
    """
    s = (
        w["ss_s"] * a["s"] * b["s"]
        + w["vv_s"] * jnp.einsum("...ci,...ci->...c", a["v"], b["v"])
        + w["tt_s"] * jnp.einsum("...cij,...cij->...c", a["t"], b["t"])
    )
    v = (
        w["sv_v"][:, None] * a["s"][..., None] * b["v"]
        + w["vs_v"][:, None] * b["s"][..., None] * a["v"]
        + w["vv_v"][:, None] * jnp.cross(a["v"], b["v"])
        + w["tv_v"][:, None] * jnp.einsum("...cij,...cj->...ci", a["t"], b["v"])
        + w["vt_v"][:, None] * jnp.einsum("...cij,...cj->...ci", b["t"], a["v"])
    )
    t = (
        w["vv_t"][:, None, None] * sym0(jnp.einsum("...ci,...cj->...cij", a["v"], b["v"]))
        + w["st_t"][:, None, None] * a["s"][..., None, None] * b["t"]
        + w["ts_t"][:, None, None] * b["s"][..., None, None] * a["t"]
        + w["tt_t"][:, None, None] * sym0(jnp.einsum("...cik,...ckj->...cij", a["t"], b["t"]))
    )
    return {"s": s, "v": v, "t": t}


_PATHS = ["ss_s", "vv_s", "tt_s", "sv_v", "vs_v", "vv_v", "tv_v", "vt_v",
          "vv_t", "st_t", "ts_t", "tt_t"]


def _init_path_weights(key, c):
    keys = jax.random.split(key, len(_PATHS))
    return {p: jax.random.normal(k, (c,), jnp.float32) * 0.5 for p, k in zip(_PATHS, keys)}


def bessel_basis(r, cfg: MACEConfig):
    n = jnp.arange(1, cfg.n_rbf + 1, dtype=jnp.float32)
    rc = cfg.cutoff
    rs = jnp.maximum(r, 1e-6)[:, None]
    basis = jnp.sqrt(2.0 / rc) * jnp.sin(n * jnp.pi * rs / rc) / rs
    # polynomial cutoff envelope (p=6)
    u = jnp.clip(r / rc, 0, 1)[:, None]
    env = 1 - 28 * u**6 + 48 * u**7 - 21 * u**8
    return basis * env


def init_params(key, cfg: MACEConfig):
    c = cfg.d_hidden
    keys = jax.random.split(key, 4 + cfg.n_layers * 8)
    params = {
        "embed": jax.random.normal(keys[0], (cfg.n_species, c), jnp.float32) * 0.3,
        "layers": [],
        "readout": common.init_mlp(keys[1], [c, c // 2, 1]),
    }
    ki = 2
    for _ in range(cfg.n_layers):
        lp = {
            # radial MLP -> per-channel weights for the 3 A-paths
            "radial": common.init_mlp(keys[ki], [cfg.n_rbf, 32, 3 * c]),
            "mix_s": jax.random.normal(keys[ki + 1], (c, c), jnp.float32) / c**0.5,
            "mix_v": jax.random.normal(keys[ki + 2], (c, c), jnp.float32) / c**0.5,
            "mix_t": jax.random.normal(keys[ki + 3], (c, c), jnp.float32) / c**0.5,
            "prod2": _init_path_weights(keys[ki + 4], c),
            "prod3": _init_path_weights(keys[ki + 5], c),
            "res": jax.random.normal(keys[ki + 6], (c, c), jnp.float32) / c**0.5,
            "layer_readout": common.init_mlp(keys[ki + 7], [c, 1]),
        }
        params["layers"].append(lp)
        ki += 8
    return params


def forward(params, g: dict, cfg: MACEConfig):
    """g: {node_feat [N] species, positions [N,3], edge_src, edge_dst,
    graph_ids?, n_graphs?} -> per-graph energies."""
    species = g["node_feat"].astype(jnp.int32)
    pos = g["positions"].astype(jnp.float32)
    src, dst = g["edge_src"], g["edge_dst"]
    n = pos.shape[0]
    c = cfg.d_hidden

    h = {
        "s": params["embed"][jnp.clip(species, 0, params["embed"].shape[0] - 1)],
        "v": jnp.zeros((n, c, 3), jnp.float32),
        "t": jnp.zeros((n, c, 3, 3), jnp.float32),
    }
    energies = jnp.zeros((n,), jnp.float32)

    def density(lp, h, src_e, dst_e):
        """A-density contribution of an edge set (exact; linear in edges)."""
        rel = common.gather(pos, src_e) - common.gather(pos, dst_e)
        emask = ((src_e < n) & (dst_e < n)).astype(jnp.float32)
        r = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-12)
        rhat = rel / jnp.maximum(r, 1e-6)[:, None]
        y1 = rhat
        y2 = sym0(jnp.einsum("ei,ej->eij", rhat, rhat)[:, None])[:, 0]
        rbf = bessel_basis(r, cfg) * emask[:, None]
        rw = common.mlp(lp["radial"], rbf).reshape(-1, 3, c)
        hs = common.gather(h["s"], src_e)
        hv = common.gather(h["v"], src_e)
        ht = common.gather(h["t"], src_e)
        a_s = rw[:, 0] * hs
        a_v = rw[:, 1][..., None] * (hs[..., None] * y1[:, None, :] + hv)
        a_t = rw[:, 2][..., None, None] * (hs[..., None, None] * y2[:, None] + ht)
        sx = cfg.shard_axes
        return {
            "s": su.maybe_constrain(common.aggregate(a_s, dst_e, n), sx),
            "v": su.maybe_constrain(common.aggregate(a_v, dst_e, n), sx),
            "t": su.maybe_constrain(common.aggregate(a_t, dst_e, n), sx),
        }

    for lp in params["layers"]:
        if cfg.edge_chunks > 1:
            e_total = src.shape[0]
            ck = -(-e_total // cfg.edge_chunks)
            pad = cfg.edge_chunks * ck - e_total
            src_p = jnp.concatenate([src, jnp.full((pad,), n, src.dtype)])
            dst_p = jnp.concatenate([dst, jnp.full((pad,), n, dst.dtype)])
            src_c = src_p.reshape(cfg.edge_chunks, ck)
            dst_c = dst_p.reshape(cfg.edge_chunks, ck)

            def body(acc, sd):
                contrib = density(lp, h, sd[0], sd[1])
                return jax.tree.map(jnp.add, acc, contrib), None

            zero = {
                "s": jnp.zeros((n, c), jnp.float32),
                "v": jnp.zeros((n, c, 3), jnp.float32),
                "t": jnp.zeros((n, c, 3, 3), jnp.float32),
            }
            agg, _ = jax.lax.scan(body, zero, (src_c, dst_c))
        else:
            agg = density(lp, h, src, dst)
        # channel mixing
        A = {
            "s": agg["s"] @ lp["mix_s"],
            "v": jnp.einsum("nci,cd->ndi", agg["v"], lp["mix_v"]),
            "t": jnp.einsum("ncij,cd->ndij", agg["t"], lp["mix_t"]),
        }
        # product basis: correlation order 2 and 3
        B2 = pairwise_product(A, A, lp["prod2"])
        B3 = pairwise_product(B2, A, lp["prod3"])
        h = {
            "s": su.maybe_constrain(h["s"] @ lp["res"] + A["s"] + B2["s"] + B3["s"], cfg.shard_axes),
            "v": su.maybe_constrain(A["v"] + B2["v"] + B3["v"], cfg.shard_axes),
            "t": su.maybe_constrain(A["t"] + B2["t"] + B3["t"], cfg.shard_axes),
        }
        energies = energies + common.mlp(lp["layer_readout"], h["s"])[:, 0]
    energies = energies + common.mlp(params["readout"], h["s"])[:, 0]
    gid = g.get("graph_ids")
    if gid is None:
        return energies.sum(keepdims=True)
    ng = int(g["n_graphs"])
    return jax.ops.segment_sum(energies, jnp.minimum(gid, ng), num_segments=ng + 1)[:ng]


def loss_fn(params, g: dict, cfg: MACEConfig):
    energy = forward(params, g, cfg)
    target = g["labels"].astype(jnp.float32)
    mse = jnp.mean((energy - target) ** 2)
    return mse, {"mse": mse}


def forces(params, g: dict, cfg: MACEConfig):
    """F = -∂E/∂pos (equivariance tested in tests/test_models.py)."""

    def e_of_pos(p):
        return forward(params, {**g, "positions": p}, cfg).sum()

    return -jax.grad(e_of_pos)(g["positions"].astype(jnp.float32))
