"""SchNet (arXiv:1706.08566): continuous-filter convolutions.

cfconv: filter W(r_ij) from an RBF expansion of interatomic distance,
message = filter ⊙ h_j, aggregated with segment_sum.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common
from .. import sharding_utils as su


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    shard_axes: tuple = ()   # mesh axes for node/edge dim-0 sharding


def init_params(key, cfg: SchNetConfig):
    keys = jax.random.split(key, cfg.n_interactions * 4 + 2)
    d = cfg.d_hidden
    params = {
        "embed": jax.random.normal(keys[0], (cfg.n_species, d), jnp.float32) * 0.1,
        "interactions": [],
        "readout": common.init_mlp(keys[1], [d, d // 2, 1]),
    }
    for i in range(cfg.n_interactions):
        k0, k1, k2, k3 = keys[2 + 4 * i : 6 + 4 * i]
        params["interactions"].append(
            {
                "filter": common.init_mlp(k0, [cfg.n_rbf, d, d]),
                "in_lin": common.init_mlp(k1, [d, d]),
                "out": common.init_mlp(k2, [d, d, d]),
            }
        )
    return params


def rbf_expand(r, cfg: SchNetConfig):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = cfg.n_rbf / cfg.cutoff
    return jnp.exp(-gamma * (r[:, None] - centers[None, :]) ** 2)


def forward(params, g: dict, cfg: SchNetConfig):
    """g: {node_feat [N] int species, positions [N,3], edge_src, edge_dst}."""
    species = g["node_feat"].astype(jnp.int32)
    pos = g["positions"].astype(jnp.float32)
    src, dst = g["edge_src"], g["edge_dst"]
    n = pos.shape[0]
    h = params["embed"][jnp.clip(species, 0, params["embed"].shape[0] - 1)]
    rel = common.gather(pos, dst) - common.gather(pos, src)
    mask = (src < n) & (dst < n)
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    rbf = rbf_expand(r, cfg) * mask[:, None]
    # smooth cutoff (cosine)
    fc = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / cfg.cutoff, 0, 1)) + 1.0)
    rbf = su.maybe_constrain(rbf, cfg.shard_axes)
    h = su.maybe_constrain(h, cfg.shard_axes)
    for ip in params["interactions"]:
        w = common.mlp(ip["filter"], rbf) * fc[:, None]
        hj = common.mlp(ip["in_lin"], h)
        msg = su.maybe_constrain(common.gather(hj, src) * w, cfg.shard_axes)
        agg = common.aggregate(msg, dst, n)
        h = su.maybe_constrain(h + common.mlp(ip["out"], agg), cfg.shard_axes)
    site_e = common.mlp(params["readout"], h)[:, 0]           # [N]
    gid = g.get("graph_ids")
    if gid is None:
        return site_e.sum(keepdims=True)
    ng = int(g["n_graphs"])
    return jax.ops.segment_sum(site_e, jnp.minimum(gid, ng), num_segments=ng + 1)[:ng]


def loss_fn(params, g: dict, cfg: SchNetConfig):
    energy = forward(params, g, cfg)
    target = g["labels"].astype(jnp.float32)
    mse = jnp.mean((energy - target) ** 2)
    return mse, {"mse": mse}
