"""Shared GNN infrastructure: padded graph batches + segment message passing.

JAX message passing = gather over an edge index + ``segment_sum`` scatter
(DESIGN.md: this substrate IS part of the system — the edge arrays come
straight from the core CSR/DiGraph representations).  Optionally the
MXU-blocked kernels (bsr_spmm / edge_segment_sum) replace the XLA scatter
on TPU (§Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import csr as csr_mod, util
from .. import sharding_utils as su

SENTINEL = util.SENTINEL


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded flat graph (single large graph or flattened molecule batch)."""

    node_feat: jnp.ndarray            # [N, F] float or [N] int (species)
    edge_src: jnp.ndarray             # [E] int32 (N = padding sink)
    edge_dst: jnp.ndarray             # [E] int32
    positions: Optional[jnp.ndarray] = None  # [N, 3]
    graph_ids: Optional[jnp.ndarray] = None  # [N] for batched molecules
    labels: Optional[jnp.ndarray] = None
    n_nodes: int = 0
    n_graphs: int = 1

    def tree_flatten(self):
        pass  # plain dataclass; passed as dict to jitted fns


def graph_batch_from_csr(c: csr_mod.CSR, node_feat, labels=None) -> GraphBatch:
    rows = np.repeat(np.arange(c.n, dtype=np.int32), np.diff(np.asarray(c.offsets)))
    return GraphBatch(
        node_feat=jnp.asarray(node_feat),
        edge_src=jnp.asarray(rows),
        edge_dst=jnp.asarray(np.asarray(c.dst)),
        labels=None if labels is None else jnp.asarray(labels),
        n_nodes=c.n,
    )


def segment_mean(vals, seg, num):
    s = jax.ops.segment_sum(vals, seg, num_segments=num)
    c = jax.ops.segment_sum(jnp.ones(vals.shape[:1], vals.dtype), seg, num_segments=num)
    return s / jnp.maximum(c[:, None] if vals.ndim > 1 else c, 1.0)


def aggregate(messages, edge_dst, n_nodes, *, mode: str = "sum"):
    """Scatter edge messages into destination nodes; padding edges must
    carry edge_dst >= n_nodes.

    The sink region is padded to 256 slots (not 1) so the scatter OUTPUT
    length stays mesh-divisible: an [N+1, d] output cannot shard on any
    axis and replicates per device (measured: the dominant HBM term for
    graphcast×ogb_products — §Perf iteration 5; same pow-2/page-rounding
    policy as core.alloc, applied to segment counts).
    """
    pad = 256
    seg = jnp.minimum(edge_dst, n_nodes)
    extra = messages.shape[1:]
    out = (
        jax.ops.segment_sum(messages, seg, num_segments=n_nodes + pad)
        if mode == "sum"
        else segment_mean(
            messages.reshape(messages.shape[0], -1), seg, n_nodes + pad
        ).reshape((n_nodes + pad,) + extra)
    )
    return out[:n_nodes]


def gather(node_vals, idx):
    """Padding-safe node gather (idx >= N returns zeros)."""
    n = node_vals.shape[0]
    safe = jnp.minimum(idx, n - 1)
    vals = node_vals[safe]
    mask = (idx < n).reshape((-1,) + (1,) * (vals.ndim - 1))
    return jnp.where(mask, vals, 0)


def mlp(params, x, act=jax.nn.silu):
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i < len(params) - 1:
            x = act(x)
    return x


def init_mlp(key, sizes, dtype=jnp.float32):
    out = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), dtype) / (
            sizes[i] ** 0.5
        )
        out.append((w, jnp.zeros((sizes[i + 1],), dtype)))
    return out
