"""Attention implementations: ref (dense scores), blocked (XLA online
softmax — the dry-run/compile path with flash-like memory), flash (Pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...kernels.flash_attention import ops as fa_ops

NEG_INF = -1e30


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x [..., S, H, Dh], positions [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def ref_attention(q, k, v, *, causal=True, window=0):
    """Dense-score attention (small shapes / tests)."""
    return fa_ops.attention_reference(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block"))
def blocked_attention(q, k, v, *, causal=True, window=0, block=512):
    """Online-softmax attention as an XLA scan over KV blocks.

    Memory O(S·block) like flash attention; expresses the same schedule in
    pure jnp so the multi-pod dry-run lowers/costs it faithfully on any
    backend.  Fully-masked blocks still execute (uniform scan) — the Pallas
    kernel's @pl.when skip is the TPU upgrade (§Perf).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    nb = skv // block
    assert skv % block == 0
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    k_blocks = k.reshape(b, hkv, nb, block, d).transpose(2, 0, 1, 3, 4)
    v_blocks = v.reshape(b, hkv, nb, block, d).transpose(2, 0, 1, 3, 4)
    q_ids = jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kb_idx = xs
        kk = jnp.repeat(kb.astype(jnp.float32), group, axis=1)  # [B,Hq,bk,D]
        vv = jnp.repeat(vb.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kk)
        k_ids = kb_idx * block + jnp.arange(block)
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= k_ids[None, :] <= q_ids[:, None]
        if window > 0:
            mask &= k_ids[None, :] > q_ids[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (k_blocks, v_blocks, jnp.arange(nb))
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention(q, k, v, *, impl="blocked", causal=True, window=0, block=512):
    if impl == "ref" or q.shape[2] <= block:
        return ref_attention(q, k, v, causal=causal, window=window)
    if impl == "blocked":
        return blocked_attention(q, k, v, causal=causal, window=window, block=block)
    if impl == "flash":
        return fa_ops.attention(
            q, k, v, causal=causal, window=window, block_q=block, block_k=block
        )
    raise ValueError(impl)


decode_attention = fa_ops.decode_attention
