"""Unified decoder-only LM: GQA / SWA / QKV-bias / MoE (+dense residual),
RoPE, RMSNorm, SwiGLU; scan-over-layers with per-layer remat.

Parameters are stacked on a leading layer axis so the compiled HLO is O(1)
in depth (and the roofline collector multiplies while-body costs by
``n_layers`` — launch/roofline.py).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .. import sharding_utils as su
from . import attention as attn_mod
from . import moe as moe_mod
from .config import TransformerConfig

Params = dict[str, Any]


def rms_norm(x, w, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def _layer_param_shapes(cfg: TransformerConfig) -> dict[str, tuple]:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    shapes = {
        "wq": (d, hq * dh),
        "wk": (d, hkv * dh),
        "wv": (d, hkv * dh),
        "wo": (hq * dh, d),
        "ln1": (d,),
        "ln2": (d,),
    }
    if cfg.qkv_bias:
        shapes |= {"bq": (hq * dh,), "bk": (hkv * dh,), "bv": (hkv * dh,)}
    if cfg.moe is None:
        shapes |= {"w1": (d, cfg.d_ff), "w3": (d, cfg.d_ff), "w2": (cfg.d_ff, d)}
    else:
        m = cfg.moe
        shapes |= {
            "router": (d, m.n_experts),
            "w1": (m.n_experts, d, m.d_ff_expert),
            "w3": (m.n_experts, d, m.d_ff_expert),
            "w2": (m.n_experts, m.d_ff_expert, d),
        }
        if m.dense_residual_ff:
            shapes |= {
                "dw1": (d, m.dense_residual_ff),
                "dw3": (d, m.dense_residual_ff),
                "dw2": (m.dense_residual_ff, d),
            }
    return shapes


def param_shapes(cfg: TransformerConfig) -> Params:
    """ShapeDtypeStructs for every parameter (used by init and dry-run)."""
    l = cfg.n_layers
    dt = cfg.param_dtype
    layers = {
        k: jax.ShapeDtypeStruct((l, *s), dt)
        for k, s in _layer_param_shapes(cfg).items()
    }
    out = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
        "layers": layers,
        "ln_f": jax.ShapeDtypeStruct((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt)
    return out


def init_params(key, cfg: TransformerConfig) -> Params:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        if len(s.shape) >= 2:
            fan_in = s.shape[-2]
            leaves.append(
                (jax.random.normal(k, s.shape, jnp.float32) / (fan_in ** 0.5)).astype(
                    s.dtype
                )
            )
        else:
            # norms start at 1, biases at 0
            fill = 1.0 if s.shape[-1] == cfg.d_model or len(s.shape) == 2 else 0.0
            leaves.append(jnp.full(s.shape, fill, s.dtype))
    params = jax.tree.unflatten(treedef, leaves)
    # norm weights exactly 1, biases exactly 0
    for name in ("ln1", "ln2"):
        params["layers"][name] = jnp.ones_like(params["layers"][name])
    for name in ("bq", "bk", "bv"):
        if name in params["layers"]:
            params["layers"][name] = jnp.zeros_like(params["layers"][name])
    params["ln_f"] = jnp.ones_like(params["ln_f"])
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _attention_block(lp, x, positions, cfg: TransformerConfig):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.compute_dtype
    h = rms_norm(x, lp["ln1"].astype(cd), cfg.norm_eps)
    q = h @ lp["wq"].astype(cd)
    k = h @ lp["wk"].astype(cd)
    v = h @ lp["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cd)
        k = k + lp["bk"].astype(cd)
        v = v + lp["bv"].astype(cd)
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = attn_mod.rope(q, positions, cfg.rope_theta)
    k = attn_mod.rope(k, positions, cfg.rope_theta)
    o = attn_mod.attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        impl=cfg.attn_impl,
        causal=True,
        window=cfg.sliding_window,
        block=cfg.attn_block,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return x + o @ lp["wo"].astype(cd)


def _ffn_block(lp, x, cfg: TransformerConfig):
    b, s, d = x.shape
    cd = cfg.compute_dtype
    h = rms_norm(x, lp["ln2"].astype(cd), cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is None:
        g = jax.nn.silu(h @ lp["w1"].astype(cd)) * (h @ lp["w3"].astype(cd))
        out = g @ lp["w2"].astype(cd)
    else:
        m = cfg.moe
        cap = moe_mod.expert_capacity(b * s, m.n_experts, m.top_k, m.capacity_factor)
        out_f, aux = moe_mod.moe_ffn(
            h.reshape(b * s, d),
            lp["router"],
            lp["w1"],
            lp["w3"],
            lp["w2"],
            top_k=m.top_k,
            capacity=cap,
            compute_dtype=cd,
            ep_axis="data" if cfg.batch_axes else "",
            token_axes=(),  # flat [B*S, D]: rely on the layer boundary wsc
        )
        out = out_f.reshape(b, s, d)
        if m.dense_residual_ff:
            g = jax.nn.silu(h @ lp["dw1"].astype(cd)) * (h @ lp["dw3"].astype(cd))
            out = out + g @ lp["dw2"].astype(cd)
    return x + out, aux


def _boundary_constraint(x, cfg: TransformerConfig):
    """Layer-boundary activation sharding: batch over the data axes AND
    sequence over the TP axis (Megatron-SP): the remat/scan-saved carries
    are then fully sharded; GSPMD all-gathers the sequence inside the
    layer where attention needs it (§Perf iteration 2)."""
    if not cfg.batch_axes:
        return x
    return su.constrain(x, tuple(cfg.batch_axes), cfg.tp_axis or None)


def _layer(lp, carry, cfg: TransformerConfig):
    x, positions = carry
    x = _attention_block(lp, x, positions, cfg)
    x = su.maybe_constrain(x, cfg.batch_axes)
    x, aux = _ffn_block(lp, x, cfg)
    x = _boundary_constraint(x, cfg)
    return x, aux


def forward(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """tokens [B, S] -> logits [B, S, V] (compute_dtype), aux losses."""
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens]
    x = _boundary_constraint(x, cfg)
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape
    )

    layer_fn = functools.partial(_layer, cfg=cfg)
    if cfg.remat:
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable
        )

    if cfg.scan_layers:
        def body(carry, lp):
            x = layer_fn(lp, (carry, positions))
            return x[0], x[1]

        x, auxes = jax.lax.scan(body, x, params["layers"])
        aux = auxes.sum()
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, a = layer_fn(lp, (x, positions))
            aux = aux + a
    x = rms_norm(x, params["ln_f"].astype(cd), cfg.norm_eps)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cd)
    logits = x @ unembed
    if cfg.batch_axes:
        logits = su.constrain(logits, tuple(cfg.batch_axes), None, cfg.tp_axis)
    return logits, aux


def loss_fn(params: Params, batch, cfg: TransformerConfig):
    """Causal LM loss: CE + z-loss + MoE aux."""
    tokens, labels = batch["tokens"], batch["labels"]
    logits, aux = forward(params, tokens, cfg)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    z_loss = 1e-4 * (lse ** 2).mean()
    return ce + z_loss + 1e-2 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------
def cache_shapes(cfg: TransformerConfig, batch: int, cache_len: int) -> Params:
    """KV cache ShapeDtypeStructs.  SWA archs get a ring of window size
    (pow-2 rounded) — the O(w) memory that makes long_500k feasible."""
    from ...core import alloc as alloc_mod

    if cfg.sliding_window > 0:
        cache_len = min(cache_len, alloc_mod.next_pow2(cfg.sliding_window))
    dh, hkv, l = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    kv = jax.ShapeDtypeStruct((l, batch, hkv, cache_len, dh), jnp.bfloat16)
    return {
        "k": kv,
        "v": kv,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: TransformerConfig, batch: int, cache_len: int) -> Params:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes(cfg, batch, cache_len)
    )


def decode_step(params: Params, cache: Params, tokens: jnp.ndarray, cfg):
    """One token per sequence: tokens [B, 1] -> (logits [B, 1, V], cache).

    The cache is a linear buffer (or ring for SWA); ``pos`` is the global
    decode position.  Buffers are donated by the serving jit.
    """
    cd = cfg.compute_dtype
    b = tokens.shape[0]
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    cache_len = cache["k"].shape[3]
    pos = cache["pos"]
    slot = jnp.where(
        cfg.sliding_window > 0, pos % cache_len, jnp.minimum(pos, cache_len - 1)
    )

    x = params["embed"].astype(cd)[tokens]                  # [B, 1, D]
    x = su.maybe_constrain(x, cfg.batch_axes)
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(carry, lp_kv):
        x = carry
        lp, k_cache, v_cache = lp_kv
        h = rms_norm(x, lp["ln1"].astype(cd), cfg.norm_eps)
        q = h @ lp["wq"].astype(cd)
        k = h @ lp["wk"].astype(cd)
        v = h @ lp["wv"].astype(cd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(cd)
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        q = attn_mod.rope(q.reshape(b, 1, hq, dh), positions, cfg.rope_theta)
        k = attn_mod.rope(k.reshape(b, 1, hkv, dh), positions, cfg.rope_theta)
        v = v.reshape(b, 1, hkv, dh)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.transpose(0, 2, 1, 3).astype(jnp.bfloat16), (0, 0, slot, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.transpose(0, 2, 1, 3).astype(jnp.bfloat16), (0, 0, slot, 0)
        )
        live = jnp.minimum(pos + 1, cache_len)
        o = attn_mod.decode_attention(
            q.transpose(0, 2, 1, 3).astype(cd),
            k_cache.astype(cd),
            v_cache.astype(cd),
            live,
        )
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
        x = x + o @ lp["wo"].astype(cd)
        # FFN (dense path for decode; MoE routes a single token per seq)
        h2 = rms_norm(x, lp["ln2"].astype(cd), cfg.norm_eps)
        if cfg.moe is None:
            g = jax.nn.silu(h2 @ lp["w1"].astype(cd)) * (h2 @ lp["w3"].astype(cd))
            x = x + g @ lp["w2"].astype(cd)
        else:
            m = cfg.moe
            cap = moe_mod.expert_capacity(b, m.n_experts, m.top_k, 2.0)
            out_f, _ = moe_mod.moe_ffn(
                h2.reshape(b, -1),
                lp["router"],
                lp["w1"],
                lp["w3"],
                lp["w2"],
                top_k=m.top_k,
                capacity=cap,
                compute_dtype=cd,
            )
            x = x + out_f.reshape(b, 1, -1)
            if m.dense_residual_ff:
                g = jax.nn.silu(h2 @ lp["dw1"].astype(cd)) * (
                    h2 @ lp["dw3"].astype(cd)
                )
                x = x + g @ lp["dw2"].astype(cd)
        x = su.maybe_constrain(x, cfg.batch_axes)
        return x, (k_cache, v_cache)

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"])
        )
    else:  # unrolled (roofline cost variants)
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, (k_i, v_i) = body(x, (lp, cache["k"][i], cache["v"][i]))
            ks.append(k_i)
            vs.append(v_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = rms_norm(x, params["ln_f"].astype(cd), cfg.norm_eps)
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cd)
    logits = x @ unembed
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return logits, new_cache
