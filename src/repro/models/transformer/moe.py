"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

Sort-based (dropping) dispatch instead of GShard one-hot matmuls: token→
expert assignments are sorted by expert, positions within each expert
computed by a segmented cumsum, tokens over capacity dropped.  FLOPs are
then dominated by the expert GEMMs (2·T·k·d·f per matmul), which is what
a roofline should see — one-hot dispatch would add a fake O(T·E·C·d) term.

Expert capacity is pow-2 bucketed (core.alloc policy): the dispatch
buffers keep a stable compiled shape as token counts vary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import alloc
from .. import sharding_utils as su


def expert_capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    raw = int(n_tokens * top_k * factor / n_experts) + 1
    return alloc.next_pow2(raw)


def moe_ffn(
    x: jnp.ndarray,            # [T, D] tokens (flattened batch*seq)
    router_w: jnp.ndarray,     # [D, E]
    w1: jnp.ndarray,           # [E, D, F]  (gate)
    w3: jnp.ndarray,           # [E, D, F]  (up)
    w2: jnp.ndarray,           # [E, F, D]  (down)
    *,
    top_k: int,
    capacity: int,
    compute_dtype=jnp.bfloat16,
    ep_axis: str = "",          # expert-parallel mesh axis (experts dim)
    token_axes: tuple = (),     # token/batch mesh axes
):
    """Returns (output [T, D], aux_loss scalar).

    Explicit EP sharding constraints: without them GSPMD replicates the
    dispatch buffers and all-reduces every expert GEMM output (measured
    52 GiB/device/layer on qwen3-moe — §Perf iteration 6).
    """
    t, d = x.shape
    e = router_w.shape[-1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)                # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    flat_expert = gate_idx.reshape(-1)                                # [T*k]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, stok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert = rank - first_rank_of_expert
    first = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(t * top_k, dtype=jnp.int32) - first[se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)         # drop -> OOB

    # dispatch via an int32 slot->token index buffer: the feature gather
    # then reads token-sharded x once (one small all-gather of x) instead
    # of scattering features across the expert sharding (§Perf iter 6b)
    idx_buf = jnp.full((e * capacity,), t, jnp.int32).at[slot].set(
        stok, mode="drop"
    )
    live = idx_buf < t
    buffers = jnp.where(
        live[:, None], x[jnp.minimum(idx_buf, t - 1)].astype(compute_dtype), 0
    )
    buffers = buffers.reshape(e, capacity, d)
    if ep_axis:
        buffers = su.constrain(buffers, ep_axis)  # [E(ep), C, D]

    # ---- expert FFN (SwiGLU), batched over experts ---------------------
    h1 = jnp.einsum("ecd,edf->ecf", buffers, w1.astype(compute_dtype))
    h3 = jnp.einsum("ecd,edf->ecf", buffers, w3.astype(compute_dtype))
    h = jax.nn.silu(h1) * h3
    if ep_axis:
        h = su.constrain(h, ep_axis)              # [E(ep), C, F]
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2.astype(compute_dtype))
    if ep_axis:
        out_buf = su.constrain(out_buf, ep_axis)  # [E(ep), C, D]
    out_buf = out_buf.reshape(e * capacity, d)

    # ---- combine back ---------------------------------------------------
    # scatter expert outputs straight into the token-sharded accumulator:
    # a per-token gather of the E-sharded out_buf all-reduces a [T·k, D]
    # f32 tensor per layer (measured 8.6 GiB); the slot->token scatter
    # all-reduces only [T, D] (§Perf iteration 6c)
    gate_buf = jnp.zeros((e * capacity,), jnp.float32).at[slot].set(
        sg, mode="drop"
    )
    contrib = out_buf * gate_buf[:, None].astype(compute_dtype)
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[jnp.minimum(idx_buf, t - 1)].add(
        jnp.where(live[:, None], contrib, 0).astype(jnp.float32)
    )
    if token_axes:
        out = su.constrain(out, tuple(token_axes))

    # ---- load-balancing aux loss (Switch) --------------------------------
    me = probs.mean(axis=0)                                  # [E]
    ce = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)
    return out.astype(compute_dtype), aux
