"""Unified LM transformer configuration covering the assigned arch pool:
dense (mistral-large, qwen2, h2o-danube w/ SWA) and MoE (qwen3-moe, arctic
w/ dense residual)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0   # arctic: parallel dense FFN width (0 = off)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False              # qwen2
    sliding_window: int = 0             # h2o-danube SWA; 0 = full attention
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    # numerics / memory policy
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    attn_impl: str = "blocked"          # ref | blocked | flash
    attn_block: int = 512               # kv block for blocked/flash impls
    scan_layers: bool = True
    # activation sharding (models/sharding_utils.py): mesh axis names for
    # the batch dim and the tensor-parallel axis; () / "" = unconstrained
    batch_axes: tuple = ()
    tp_axis: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.sliding_window > 0

    def n_params(self) -> int:
        """Total parameter count (embeddings included)."""
        d, l = self.d_model, self.n_layers
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        if self.qkv_bias:
            attn += (hq + 2 * hkv) * dh
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            ff += d * self.moe.n_experts  # router
            if self.moe.dense_residual_ff:
                ff += 3 * d * self.moe.dense_residual_ff
        else:
            ff = 3 * d * self.d_ff
        norms = 2 * d * l + d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ff) + norms + emb

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        ff = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        ff += d * self.moe.n_experts
        if self.moe.dense_residual_ff:
            ff += 3 * d * self.moe.dense_residual_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ff) + 2 * d * l + d + emb
