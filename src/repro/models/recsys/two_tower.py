"""Two-tower retrieval (Yi et al., RecSys'19): sampled-softmax retrieval.

Each tower: EmbeddingBag over sparse feature fields (the hot path — JAX
has no native EmbeddingBag; we build it from take + segment_sum, with the
Pallas scalar-prefetch kernel as the TPU upgrade) → MLP → L2-normalized
embedding.  Training: in-batch sampled softmax with logQ correction.
Serving: dot-product scoring, incl. the 10⁶-candidate bulk-scoring shape
(one batched matmul, not a loop).

Dynamic-graph tie-in (DESIGN.md §4): the user→item interaction graph is a
core.DiGraph; streaming interactions arrive as EdgeBatch insertions and the
per-user history bags are exactly its adjacency rows.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from ...kernels.embedding_bag import ops as bag_ops
from .. import sharding_utils as su


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Sequence[int] = (1024, 512, 256)
    interaction: str = "dot"
    shard_axes: tuple = ()       # mesh axes for the batch dim
    n_users: int = 10_000_000
    n_items: int = 10_000_000
    n_user_fields: int = 4       # multi-hot history bags per user example
    n_item_fields: int = 2
    bag_size: int = 16           # indices per field (pow-2)
    temperature: float = 0.05
    use_kernel: bool = False     # Pallas bag kernel (TPU); jnp path otherwise


def _tower_shapes(cfg, vocab, n_fields):
    sizes = [n_fields * cfg.embed_dim, *cfg.tower_mlp]
    return {
        "table": (vocab, cfg.embed_dim),
        "mlp": [(sizes[i], sizes[i + 1]) for i in range(len(sizes) - 1)],
    }


def init_params(key, cfg: TwoTowerConfig):
    def tower(key, vocab, n_fields):
        sh = _tower_shapes(cfg, vocab, n_fields)
        keys = jax.random.split(key, len(sh["mlp"]) + 1)
        return {
            "table": jax.random.normal(keys[0], sh["table"], jnp.float32) * 0.01,
            "mlp": [
                {
                    "w": jax.random.normal(k, s, jnp.float32) / (s[0] ** 0.5),
                    "b": jnp.zeros((s[1],), jnp.float32),
                }
                for k, s in zip(keys[1:], sh["mlp"])
            ],
        }

    ku, ki = jax.random.split(key)
    return {
        "user": tower(ku, cfg.n_users, cfg.n_user_fields),
        "item": tower(ki, cfg.n_items, cfg.n_item_fields),
    }


def tower_forward(tp, bags, cfg: TwoTowerConfig):
    """bags [B, n_fields, K] int32 (-1 pad) -> [B, embed_dim] normalized."""
    b, nf, k = bags.shape
    pooled = bag_ops.embedding_bag(
        tp["table"],
        bags.reshape(b * nf, k),
        combine="mean",
        use_kernel=cfg.use_kernel,
    )
    x = pooled.reshape(b, nf * cfg.embed_dim)
    x = su.maybe_constrain(x, cfg.shard_axes)
    for i, lp in enumerate(tp["mlp"]):
        x = x @ lp["w"] + lp["b"]
        if i < len(tp["mlp"]) - 1:
            x = jax.nn.relu(x)
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
    return su.maybe_constrain(x, cfg.shard_axes)


def loss_fn(params, batch, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction.

    batch: {user_bags [B,nf,K], item_bags [B,nf,K], item_logq [B]}.
    """
    u = tower_forward(params["user"], batch["user_bags"], cfg)
    v = tower_forward(params["item"], batch["item_bags"], cfg)
    logits = (u @ v.T) / cfg.temperature                  # [B, B]
    logits = logits - batch["item_logq"][None, :]          # logQ correction
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = (lse - gold).mean()
    return ce, {"ce": ce}


def score_candidates(params, user_bags, cand_bags, cfg: TwoTowerConfig):
    """retrieval_cand shape: 1 query × n_candidates — one batched matmul."""
    u = tower_forward(params["user"], user_bags, cfg)        # [1, D]
    v = tower_forward(params["item"], cand_bags, cfg)        # [C, D]
    return (u @ v.T)[0]                                      # [C]


def serve_step(params, batch, cfg: TwoTowerConfig):
    """Online/bulk inference: score user-item pairs."""
    u = tower_forward(params["user"], batch["user_bags"], cfg)
    v = tower_forward(params["item"], batch["item_bags"], cfg)
    return jnp.sum(u * v, axis=-1)
