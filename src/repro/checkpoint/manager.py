"""Checkpointing: atomic, rotated, restart-from-latest.

Fault-tolerance contract (DESIGN.md §5): a step is durable once its
directory is atomically renamed into place; restart picks the newest
complete checkpoint; rotation bounds disk.  Pytrees are stored as one
``.npz`` per checkpoint plus a JSON manifest of the tree structure, so a
restore can validate structure before touching device memory.  On real
multi-host topologies each host writes its own shard files under the same
step directory (``shard_id``); this container exercises the single-shard
path plus the manifest/rotation/atomicity machinery.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write checkpoint for ``step``; atomic rename; rotate old ones."""
    arrays, _ = _flatten_with_paths(tree)
    return save_arrays(ckpt_dir, step, arrays, keep=keep, shard_id=shard_id)


def save_arrays(
    ckpt_dir: str,
    step: int,
    arrays: dict,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write a flat ``{key: ndarray}`` checkpoint (the graph-state path).

    Same atomic-rename protocol as :func:`save`, without requiring the
    state to be a pytree — representations hand over their
    ``state_tree()`` dicts directly.  The ``checkpoint.pre_rename``
    injection point simulates a crash between the tmp-dir write and the
    commit rename; like a real crash it leaves the ``.tmp_ckpt_*``
    debris in place (recovery sweeps it via :func:`clean_stale`), which
    is why only the SimulatedCrash branch skips cleanup below.
    """
    from ..runtime import faultinject  # lazy: checkpoint stays import-light

    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "n_shards": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        faultinject.fire("checkpoint.pre_rename")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity: rename is the commit point
    except faultinject.SimulatedCrash:
        raise  # crashed writers don't clean up after themselves
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def restore_arrays(ckpt_dir: str, *, step: Optional[int] = None) -> tuple[dict, int]:
    """Manifest-driven flat restore — no ``like`` template required.

    The recovery path has no live object to mirror (the process that
    owned the shapes is gone), so the manifest is the source of truth:
    every key must load with exactly its recorded shape and dtype.
    Returns ``({key: ndarray}, step)``.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"), allow_pickle=False)
    if set(data.files) != set(manifest["keys"]):
        raise ValueError(
            f"checkpoint {d}: npz keys disagree with manifest"
        )
    out = {}
    for k in manifest["keys"]:
        v = data[k]
        if list(v.shape) != manifest["shapes"][k] or str(v.dtype) != manifest["dtypes"][k]:
            raise ValueError(
                f"checkpoint {d}: {k} is {v.shape}/{v.dtype}, manifest says "
                f"{manifest['shapes'][k]}/{manifest['dtypes'][k]}"
            )
        out[k] = v
    return out, int(step)


def clean_stale(ckpt_dir: str) -> list[str]:
    """Sweep ``.tmp_ckpt_*`` debris left by writers that died pre-commit.

    Recovery calls this first: an interrupted checkpoint never renamed
    into place, so its tmp dir is garbage by construction.
    """
    removed = []
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith(".tmp_ckpt_"):
                shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
                removed.append(name)
    return removed


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step).

    Validates manifest keys/shapes against ``like`` first — a structure
    mismatch (code drift vs checkpoint) fails loudly before any device
    allocation.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    want, treedef = _flatten_with_paths(like)
    missing = set(want) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(want)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    for k, v in want.items():
        if list(data[k].shape) != list(v.shape):
            raise ValueError(f"shape mismatch for {k}: {data[k].shape} vs {v.shape}")
    leaves_sorted = {k: data[k] for k in want}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        restored.append(
            jax.numpy.asarray(leaves_sorted[key], dtype=leaf.dtype)
            if hasattr(leaf, "dtype")
            else leaves_sorted[key]
        )
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), restored), step


def checkpoint_hook(ckpt_dir: str, every: int, *, keep: int = 3):
    """Training-loop hook: persist state every N steps."""

    def hook(step: int, state):
        if step > 0 and step % every == 0:
            save(ckpt_dir, step, state, keep=keep)
        return state

    return hook
