"""Checkpointing: atomic, rotated, restart-from-latest.

Fault-tolerance contract (DESIGN.md §5): a step is durable once its
directory is atomically renamed into place; restart picks the newest
complete checkpoint; rotation bounds disk.  Pytrees are stored as one
``.npz`` per checkpoint plus a JSON manifest of the tree structure, so a
restore can validate structure before touching device memory.  Sharded
owners (the §14 multi-device walk images) write one ``shard_{id}.npz``
per device under ONE shared step manifest via
:func:`save_arrays_sharded` — the atomic rename commits all shards or
none.

**Differential checkpoints (DESIGN.md §15).**  Full manifests carry a
per-key list of ``CHUNK_BYTES``-granular CRC32 digests.
:func:`save_arrays_diff` writes a step that persists only the chunks
that changed since ``base_step`` — detected by hashing against the
base manifest's digests, or told directly via ``dirty`` hints (the
WAL-window dirty-block set the durability layer derives from
``UpdatePlan`` rows and image block geometry, so the hash pass is
skipped for tracked shards and untouched shards cost zero bytes AND
zero work).  Diff manifests chain through ``base_step`` and always
carry the FULL logical key/shape/dtype/digest set, so any diff step is
a complete restore point: :func:`restore_arrays_diff` loads the chain's
full base and patches chunks forward, verifying persisted-chunk CRCs.
Rotation is chain-aware — a base is never rotated out from under a
kept diff.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

#: Dirty-block granularity of differential checkpoints.  16 KiB keeps
#: manifests small (one digest per chunk) while a single-row patch still
#: persists only a few chunks of the slot arrays.
CHUNK_BYTES = 1 << 14


def _chunk_crcs(buf: bytes) -> list:
    """CRC32 digest per CHUNK_BYTES chunk of ``buf`` (empty → [])."""
    return [
        zlib.crc32(buf[i : i + CHUNK_BYTES])
        for i in range(0, len(buf), CHUNK_BYTES)
    ]


def _ranges_to_chunks(ranges, itemsize: int, nbytes: int) -> np.ndarray:
    """Chunk ids covered by half-open ELEMENT ranges ``[(lo, hi), ...]``.

    The durability layer hands dirty hints in element units (rows, slot
    extents); the byte scale is the key's own itemsize.  Ids are clipped
    to the chunks that actually exist for an ``nbytes``-long buffer.
    """
    r = np.asarray(ranges, dtype=np.int64).reshape(-1, 2)
    n_chunks = (nbytes + CHUNK_BYTES - 1) // CHUNK_BYTES
    if r.shape[0] == 0 or n_chunks == 0:
        return np.empty(0, dtype=np.int64)
    r = r[r[:, 1] > r[:, 0]]
    if r.shape[0] == 0:
        return np.empty(0, dtype=np.int64)
    lo = (r[:, 0] * itemsize) // CHUNK_BYTES
    hi = (r[:, 1] * itemsize - 1) // CHUNK_BYTES  # inclusive
    ids = np.concatenate(
        [np.arange(a, b + 1, dtype=np.int64) for a, b in zip(lo, hi)]
    )
    ids = np.unique(ids)
    return ids[(ids >= 0) & (ids < n_chunks)]


def _read_manifest(step_dir: str) -> dict:
    with open(os.path.join(step_dir, "manifest.json")) as f:
        return json.load(f)


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{int(step):010d}")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write checkpoint for ``step``; atomic rename; rotate old ones."""
    arrays, _ = _flatten_with_paths(tree)
    return save_arrays(ckpt_dir, step, arrays, keep=keep, shard_id=shard_id)


def save_arrays(
    ckpt_dir: str,
    step: int,
    arrays: dict,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write a flat ``{key: ndarray}`` checkpoint (the graph-state path).

    Same atomic-rename protocol as :func:`save`, without requiring the
    state to be a pytree — representations hand over their
    ``state_tree()`` dicts directly.  ``shard_id`` names the shard file
    (``shard_{id}.npz``); multi-shard owners use
    :func:`save_arrays_sharded` so every shard commits under ONE step
    manifest and one atomic rename.
    """
    return save_arrays_sharded(
        ckpt_dir, step, {int(shard_id): arrays}, keep=keep
    )


def save_arrays_sharded(
    ckpt_dir: str,
    step: int,
    shards: dict,
    *,
    keep: int = 3,
) -> str:
    """Write ``{shard_id: {key: ndarray}}`` — one file per shard, one
    shared step manifest (DESIGN.md §14).

    All shard files land in the same tmp dir, so the atomic-rename
    commit point covers the whole mesh: a step is either durable for
    every shard or for none.  The ``checkpoint.pre_rename`` injection
    point simulates a crash between the tmp-dir write and the commit
    rename; like a real crash it leaves the ``.tmp_ckpt_*`` debris in
    place (recovery sweeps it via :func:`clean_stale`), which is why
    only the SimulatedCrash branch skips cleanup below.
    """
    from ..runtime import faultinject  # lazy: checkpoint stays import-light

    if not shards:
        raise ValueError("save_arrays_sharded: no shards to write")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        manifest = {
            "step": step,
            "kind": "full",
            "n_shards": len(shards),
            "shards": {},
        }
        for sid in sorted(shards):
            arrays = {k: np.asarray(v) for k, v in shards[sid].items()}
            np.savez(os.path.join(tmp, f"shard_{sid}.npz"), **arrays)
            manifest["shards"][str(sid)] = {
                "keys": sorted(arrays.keys()),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                # per-key chunk digests: the anchor future diff steps
                # hash/patch against (§15)
                "chunks": {k: _chunk_crcs(v.tobytes()) for k, v in arrays.items()},
            }
        if len(shards) == 1:
            # legacy flat fields: single-shard manifests stay readable by
            # pre-§14 restores (and restore() below)
            (only,) = manifest["shards"].values()
            manifest.update(only)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        faultinject.fire("checkpoint.pre_rename")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity: rename is the commit point
    except faultinject.SimulatedCrash:
        raise  # crashed writers don't clean up after themselves
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def save_arrays_diff(
    ckpt_dir: str,
    step: int,
    shards: dict,
    *,
    base_step: Optional[int] = None,
    keep: int = 3,
    dirty: Optional[dict] = None,
) -> str:
    """Write a differential step: only chunks changed since ``base_step``.

    ``shards`` is the FULL current state (``{shard_id: {key: ndarray}}``
    — same shape as :func:`save_arrays_sharded`); what shrinks is the
    persisted payload, never the manifest's logical coverage, so every
    diff step is a complete restore point for :func:`restore_arrays_diff`.
    ``base_step`` defaults to the latest existing step (diff-on-diff
    chains are fine; restore walks the chain back to a full base).

    ``dirty`` optionally narrows the work per shard:

    - absent / ``None`` per shard → hash-compare every chunk against the
      base manifest digests (exact, costs one pass over the state);
    - ``"clean"`` → persist nothing for the shard (shapes verified);
    - ``"full"`` → persist the whole shard;
    - ``{key: hint}`` with per-key ``"clean"`` / ``"full"`` / ``None`` /
      an ``[(lo, hi), ...]`` array of half-open ELEMENT ranges — ranged
      keys persist exactly the covered chunks with no hashing.

    Keys whose shape/dtype changed vs the base, or that the base has no
    digests for (legacy manifests), degrade to full persistence of that
    key.  Changed chunks are stored as ``{key}::idx`` (chunk ids) +
    ``{key}::dat`` (raw bytes) npz entries; fully-replaced keys keep
    their plain name.
    """
    from ..runtime import faultinject  # lazy: checkpoint stays import-light

    if not shards:
        raise ValueError("save_arrays_diff: no shards to write")
    if base_step is None:
        base_step = latest_step(ckpt_dir)
    if base_step is None:
        raise FileNotFoundError(
            f"save_arrays_diff: no base checkpoint under {ckpt_dir}"
        )
    base_man = _read_manifest(_step_dir(ckpt_dir, base_step))
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        manifest = {
            "step": step,
            "kind": "diff",
            "base_step": int(base_step),
            "n_shards": len(shards),
            "shards": {},
        }
        for sid in sorted(shards):
            arrays = {k: np.asarray(v) for k, v in shards[sid].items()}
            try:
                base_blk = _shard_manifest(base_man, int(sid), "")
            except FileNotFoundError:
                base_blk = None  # shard count changed: persist fully
            shard_hint = (dirty or {}).get(sid)
            entries, chunks_out, diff_bytes = {}, {}, 0
            for k in sorted(arrays):
                arr = arrays[k]
                buf = arr.tobytes()
                base_ok = (
                    base_blk is not None
                    and k in base_blk.get("chunks", {})
                    and base_blk["shapes"].get(k) == list(arr.shape)
                    and base_blk["dtypes"].get(k) == str(arr.dtype)
                )
                if isinstance(shard_hint, dict):
                    key_hint = shard_hint.get(k)
                else:
                    key_hint = shard_hint  # None / "clean" / "full"
                if not base_ok or (isinstance(key_hint, str) and key_hint == "full"):
                    entries[k] = arr
                    chunks_out[k] = _chunk_crcs(buf)
                    diff_bytes += len(buf)
                    continue
                base_crcs = base_blk["chunks"][k]
                if isinstance(key_hint, str) and key_hint == "clean":
                    # shape/dtype matched above; carry the base digests
                    chunks_out[k] = list(base_crcs)
                    continue
                if key_hint is None:  # hash-compare against the base
                    crcs = _chunk_crcs(buf)
                    ids = np.asarray(
                        [i for i, (a, b) in enumerate(zip(crcs, base_crcs)) if a != b],
                        dtype=np.int64,
                    )
                    chunks_out[k] = crcs
                else:  # element ranges: persist exactly the covered chunks
                    ids = _ranges_to_chunks(key_hint, max(arr.dtype.itemsize, 1), len(buf))
                    crcs = list(base_crcs)
                    for i in ids:
                        i = int(i)
                        crcs[i] = zlib.crc32(buf[i * CHUNK_BYTES : (i + 1) * CHUNK_BYTES])
                    chunks_out[k] = crcs
                if ids.size:
                    dat = b"".join(
                        buf[int(i) * CHUNK_BYTES : (int(i) + 1) * CHUNK_BYTES]
                        for i in ids
                    )
                    entries[f"{k}::idx"] = ids
                    entries[f"{k}::dat"] = np.frombuffer(dat, dtype=np.uint8)
                    diff_bytes += len(dat)
            manifest["shards"][str(sid)] = {
                "keys": sorted(arrays.keys()),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "chunks": chunks_out,
                "diff_bytes": int(diff_bytes),
            }
            if entries:
                np.savez(os.path.join(tmp, f"shard_{sid}.npz"), **entries)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        faultinject.fire("checkpoint.pre_rename")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity: rename is the commit point
    except faultinject.SimulatedCrash:
        raise  # crashed writers don't clean up after themselves
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def _shard_manifest(manifest: dict, shard_id: int, where: str) -> dict:
    """The {keys, shapes, dtypes} block for one shard of a manifest."""
    per = manifest.get("shards")
    if per is not None:
        blk = per.get(str(shard_id))
        if blk is None:
            raise FileNotFoundError(
                f"checkpoint {where}: no shard {shard_id} in manifest "
                f"(has {sorted(per)})"
            )
        return blk
    if shard_id != 0:  # pre-§14 manifest: flat fields, single shard
        raise FileNotFoundError(
            f"checkpoint {where}: legacy single-shard manifest has no "
            f"shard {shard_id}"
        )
    return manifest


def restore_arrays(
    ckpt_dir: str, *, step: Optional[int] = None, shard_id: int = 0
) -> tuple[dict, int]:
    """Manifest-driven flat restore — no ``like`` template required.

    The recovery path has no live object to mirror (the process that
    owned the shapes is gone), so the manifest is the source of truth:
    every key must load with exactly its recorded shape and dtype.
    ``shard_id`` selects one shard file of a sharded step manifest.
    Returns ``({key: ndarray}, step)``.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    manifest = _read_manifest(d)
    if manifest.get("kind", "full") == "diff":
        shards, step = restore_arrays_diff(
            ckpt_dir, step=step, only_shard=int(shard_id)
        )
        return shards[int(shard_id)], int(step)
    blk = _shard_manifest(manifest, int(shard_id), d)
    data = np.load(
        os.path.join(d, f"shard_{int(shard_id)}.npz"), allow_pickle=False
    )
    if set(data.files) != set(blk["keys"]):
        raise ValueError(
            f"checkpoint {d}: shard {shard_id} npz keys disagree with manifest"
        )
    out = {}
    for k in blk["keys"]:
        v = data[k]
        if list(v.shape) != blk["shapes"][k] or str(v.dtype) != blk["dtypes"][k]:
            raise ValueError(
                f"checkpoint {d}: {k} is {v.shape}/{v.dtype}, manifest says "
                f"{blk['shapes'][k]}/{blk['dtypes'][k]}"
            )
        out[k] = v
    return out, int(step)


def restore_arrays_sharded(
    ckpt_dir: str, *, step: Optional[int] = None
) -> tuple[dict, int]:
    """Restore every shard of a step: ``({shard_id: arrays}, step)``.

    Legacy single-shard manifests come back as ``{0: arrays}``;
    differential steps are resolved through their chain via
    :func:`restore_arrays_diff`.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    manifest = _read_manifest(d)
    if manifest.get("kind", "full") == "diff":
        return restore_arrays_diff(ckpt_dir, step=step)
    sids = (
        sorted(int(s) for s in manifest["shards"])
        if manifest.get("shards") is not None
        else [0]
    )
    return (
        {s: restore_arrays(ckpt_dir, step=step, shard_id=s)[0] for s in sids},
        int(step),
    )


def restore_arrays_diff(
    ckpt_dir: str, *, step: Optional[int] = None,
    only_shard: Optional[int] = None,
) -> tuple[dict, int]:
    """Chain-walking restore: ``({shard_id: arrays}, step)`` for any step.

    Walks ``base_step`` links back to a full checkpoint, loads that base,
    then patches each diff step's persisted chunks forward in order.
    Every patched chunk is verified against the manifest's CRC digest,
    and when the chain actually has diffs the BASE payload is verified
    against its own manifest digests first — patching chunks into a
    silently rotten base would otherwise launder the damage into a
    "successful" restore.  Any failure (missing step, corrupt manifest,
    CRC mismatch, cycle, shape drift) raises BEFORE any state escapes —
    the caller never sees partially patched arrays.  Works on full steps
    too (a chain of length one), so recovery can call this
    unconditionally.

    ``only_shard`` restricts the whole walk to one shard id — the §17
    single-shard online rebuild path; other shards are neither loaded
    nor verified.  A shard first materialized by a mid-chain diff (a
    shard-count change) simply has no base to load.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    chain, s, seen = [], int(step), set()
    while True:
        d = _step_dir(ckpt_dir, s)
        if not os.path.exists(os.path.join(d, "manifest.json")):
            raise FileNotFoundError(
                f"diff chain for step {step} broken: step {s} is missing "
                f"from {ckpt_dir}"
            )
        try:
            man = _read_manifest(d)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ValueError(
                f"diff chain for step {step}: step {s} manifest is corrupt "
                f"({e}); restore aborted before patching"
            ) from e
        chain.append((s, d, man))
        if man.get("kind", "full") != "diff":
            break
        b = man.get("base_step")
        if b is None or int(b) >= s or s in seen:
            raise ValueError(f"diff chain corrupt at step {s} (base={b})")
        seen.add(s)
        s = int(b)
    chain.reverse()
    base_step, base_dir, base_man = chain[0]
    if only_shard is None:
        shards = {
            sid: dict(arrs)
            for sid, arrs in restore_arrays_sharded(
                ckpt_dir, step=base_step
            )[0].items()
        }
    else:
        try:
            arrs, _ = restore_arrays(
                ckpt_dir, step=base_step, shard_id=int(only_shard)
            )
            shards = {int(only_shard): dict(arrs)}
        except FileNotFoundError:
            if len(chain) == 1:
                raise
            # the shard first appears in a later diff of the chain
            shards = {}
    if len(chain) > 1:
        # base-payload integrity gate: verify the loaded base bytes
        # against the base manifest's own chunk digests before any diff
        # chunk patches into them
        for sid, arrs in shards.items():
            blk = _shard_manifest(base_man, sid, base_dir)
            digests = blk.get("chunks")
            if digests is None:
                continue  # pre-§15 base manifest: nothing to check against
            for k, v in arrs.items():
                want = digests.get(k)
                if want is None:
                    continue
                got = _chunk_crcs(np.asarray(v).tobytes())
                if got != want:
                    bad = [i for i, (a, b2) in enumerate(zip(want, got))
                           if a != b2][:4]
                    raise ValueError(
                        f"base step {base_step}: shard {sid} key {k} payload "
                        f"is corrupt (chunks {bad} fail their CRC digests); "
                        f"restore aborted before patching"
                    )
    for s, d, man in chain[1:]:
        for sid_s, blk in man["shards"].items():
            sid = int(sid_s)
            if only_shard is not None and sid != int(only_shard):
                continue
            cur = shards.get(sid, {})
            npz_path = os.path.join(d, f"shard_{sid}.npz")
            data = (
                np.load(npz_path, allow_pickle=False)
                if os.path.exists(npz_path)
                else None
            )
            out = {}
            for k in blk["keys"]:
                shape, dt = blk["shapes"][k], blk["dtypes"][k]
                if data is not None and k in data.files:
                    v = data[k]
                elif data is not None and f"{k}::idx" in data.files:
                    basev = cur.get(k)
                    if basev is None or list(basev.shape) != shape or str(
                        basev.dtype
                    ) != dt:
                        raise ValueError(
                            f"diff step {s}: no compatible base value for {k}"
                        )
                    buf = bytearray(np.asarray(basev).tobytes())
                    ids = data[f"{k}::idx"]
                    dat = data[f"{k}::dat"].tobytes()
                    off = 0
                    digests = blk.get("chunks", {}).get(k)
                    for i in ids:
                        i = int(i)
                        lo = i * CHUNK_BYTES
                        hi = min(lo + CHUNK_BYTES, len(buf))
                        n = hi - lo
                        buf[lo:hi] = dat[off : off + n]
                        off += n
                        if digests is not None and zlib.crc32(
                            bytes(buf[lo:hi])
                        ) != digests[i]:
                            raise ValueError(
                                f"diff step {s}: chunk {i} of {k} fails its "
                                f"CRC digest"
                            )
                    # .copy(): frombuffer views are read-only and restored
                    # state must stay mutable for the live patch path
                    v = (
                        np.frombuffer(bytes(buf), dtype=np.dtype(dt))
                        .reshape(shape)
                        .copy()
                    )
                else:
                    v = cur.get(k)
                    if v is None:
                        raise ValueError(
                            f"diff step {s}: {k} carried forward but absent "
                            f"from base"
                        )
                if list(np.asarray(v).shape) != shape or str(v.dtype) != dt:
                    raise ValueError(
                        f"diff step {s}: {k} is {np.asarray(v).shape}/{v.dtype},"
                        f" manifest says {shape}/{dt}"
                    )
                out[k] = v
            shards[sid] = out
        # shard-count changes drop shards absent from the newest manifest
        shards = {
            int(x): shards[int(x)]
            for x in man["shards"]
            if int(x) in shards
        }
    if only_shard is not None and int(only_shard) not in shards:
        raise FileNotFoundError(
            f"checkpoint step {step}: no shard {only_shard} in the diff "
            f"chain (has {sorted(shards)})"
        )
    return shards, int(step)


def restore_shard_diff(
    ckpt_dir: str, shard_id: int, *, step: Optional[int] = None
) -> tuple[dict, int]:
    """Restore ONE shard's arrays through its diff chain: ``(arrays, step)``.

    The §17 online-rebuild entry point: loads and verifies only
    ``shard_{shard_id}.npz`` files along the chain — the surviving
    shards' (much larger) payloads are never read.
    """
    shards, s = restore_arrays_diff(
        ckpt_dir, step=step, only_shard=int(shard_id)
    )
    return shards[int(shard_id)], s


def clean_stale(ckpt_dir: str) -> list[str]:
    """Sweep ``.tmp_ckpt_*`` debris left by writers that died pre-commit.

    Recovery calls this first: an interrupted checkpoint never renamed
    into place, so its tmp dir is garbage by construction.
    """
    removed = []
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith(".tmp_ckpt_"):
                shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
                removed.append(name)
    return removed


def _rotate(ckpt_dir: str, keep: int) -> None:
    """Drop all but the newest ``keep`` steps — chain-aware: the full
    base (and intermediate diffs) a kept diff step restores through are
    never rotated out from under it."""
    steps = sorted(all_steps(ckpt_dir))
    have = set(steps)
    keep_set = set(steps[-keep:]) if keep > 0 else set()
    frontier = list(keep_set)
    while frontier:
        s = frontier.pop()
        try:
            man = _read_manifest(_step_dir(ckpt_dir, s))
        except (OSError, json.JSONDecodeError):
            continue
        b = man.get("base_step")
        if b is not None and int(b) in have and int(b) not in keep_set:
            keep_set.add(int(b))
            frontier.append(int(b))
    for s in steps:
        if s not in keep_set:
            shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step).

    Validates manifest keys/shapes against ``like`` first — a structure
    mismatch (code drift vs checkpoint) fails loudly before any device
    allocation.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    want, treedef = _flatten_with_paths(like)
    missing = set(want) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(want)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    for k, v in want.items():
        if list(data[k].shape) != list(v.shape):
            raise ValueError(f"shape mismatch for {k}: {data[k].shape} vs {v.shape}")
    leaves_sorted = {k: data[k] for k in want}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        restored.append(
            jax.numpy.asarray(leaves_sorted[key], dtype=leaf.dtype)
            if hasattr(leaf, "dtype")
            else leaves_sorted[key]
        )
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), restored), step


def checkpoint_hook(ckpt_dir: str, every: int, *, keep: int = 3):
    """Training-loop hook: persist state every N steps."""

    def hook(step: int, state):
        if step > 0 and step % every == 0:
            save(ckpt_dir, step, state, keep=keep)
        return state

    return hook
