"""Checkpointing: atomic, rotated, restart-from-latest.

Fault-tolerance contract (DESIGN.md §5): a step is durable once its
directory is atomically renamed into place; restart picks the newest
complete checkpoint; rotation bounds disk.  Pytrees are stored as one
``.npz`` per checkpoint plus a JSON manifest of the tree structure, so a
restore can validate structure before touching device memory.  On real
multi-host topologies each host writes its own shard files under the same
step directory (``shard_id``); this container exercises the single-shard
path plus the manifest/rotation/atomicity machinery.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write checkpoint for ``step``; atomic rename; rotate old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        arrays, _ = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "n_shards": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity: rename is the commit point
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step).

    Validates manifest keys/shapes against ``like`` first — a structure
    mismatch (code drift vs checkpoint) fails loudly before any device
    allocation.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    want, treedef = _flatten_with_paths(like)
    missing = set(want) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(want)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    for k, v in want.items():
        if list(data[k].shape) != list(v.shape):
            raise ValueError(f"shape mismatch for {k}: {data[k].shape} vs {v.shape}")
    leaves_sorted = {k: data[k] for k in want}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        restored.append(
            jax.numpy.asarray(leaves_sorted[key], dtype=leaf.dtype)
            if hasattr(leaf, "dtype")
            else leaves_sorted[key]
        )
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), restored), step


def checkpoint_hook(ckpt_dir: str, every: int, *, keep: int = 3):
    """Training-loop hook: persist state every N steps."""

    def hook(step: int, state):
        if step > 0 and step % every == 0:
            save(ckpt_dir, step, state, keep=keep)
        return state

    return hook
