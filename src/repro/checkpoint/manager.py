"""Checkpointing: atomic, rotated, restart-from-latest.

Fault-tolerance contract (DESIGN.md §5): a step is durable once its
directory is atomically renamed into place; restart picks the newest
complete checkpoint; rotation bounds disk.  Pytrees are stored as one
``.npz`` per checkpoint plus a JSON manifest of the tree structure, so a
restore can validate structure before touching device memory.  Sharded
owners (the §14 multi-device walk images) write one ``shard_{id}.npz``
per device under ONE shared step manifest via
:func:`save_arrays_sharded` — the atomic rename commits all shards or
none; restore replays shards serially for now.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write checkpoint for ``step``; atomic rename; rotate old ones."""
    arrays, _ = _flatten_with_paths(tree)
    return save_arrays(ckpt_dir, step, arrays, keep=keep, shard_id=shard_id)


def save_arrays(
    ckpt_dir: str,
    step: int,
    arrays: dict,
    *,
    keep: int = 3,
    shard_id: int = 0,
) -> str:
    """Write a flat ``{key: ndarray}`` checkpoint (the graph-state path).

    Same atomic-rename protocol as :func:`save`, without requiring the
    state to be a pytree — representations hand over their
    ``state_tree()`` dicts directly.  ``shard_id`` names the shard file
    (``shard_{id}.npz``); multi-shard owners use
    :func:`save_arrays_sharded` so every shard commits under ONE step
    manifest and one atomic rename.
    """
    return save_arrays_sharded(
        ckpt_dir, step, {int(shard_id): arrays}, keep=keep
    )


def save_arrays_sharded(
    ckpt_dir: str,
    step: int,
    shards: dict,
    *,
    keep: int = 3,
) -> str:
    """Write ``{shard_id: {key: ndarray}}`` — one file per shard, one
    shared step manifest (DESIGN.md §14).

    All shard files land in the same tmp dir, so the atomic-rename
    commit point covers the whole mesh: a step is either durable for
    every shard or for none.  The ``checkpoint.pre_rename`` injection
    point simulates a crash between the tmp-dir write and the commit
    rename; like a real crash it leaves the ``.tmp_ckpt_*`` debris in
    place (recovery sweeps it via :func:`clean_stale`), which is why
    only the SimulatedCrash branch skips cleanup below.
    """
    from ..runtime import faultinject  # lazy: checkpoint stays import-light

    if not shards:
        raise ValueError("save_arrays_sharded: no shards to write")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        manifest = {"step": step, "n_shards": len(shards), "shards": {}}
        for sid in sorted(shards):
            arrays = {k: np.asarray(v) for k, v in shards[sid].items()}
            np.savez(os.path.join(tmp, f"shard_{sid}.npz"), **arrays)
            manifest["shards"][str(sid)] = {
                "keys": sorted(arrays.keys()),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            }
        if len(shards) == 1:
            # legacy flat fields: single-shard manifests stay readable by
            # pre-§14 restores (and restore() below)
            (only,) = manifest["shards"].values()
            manifest.update(only)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        faultinject.fire("checkpoint.pre_rename")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomicity: rename is the commit point
    except faultinject.SimulatedCrash:
        raise  # crashed writers don't clean up after themselves
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def _shard_manifest(manifest: dict, shard_id: int, where: str) -> dict:
    """The {keys, shapes, dtypes} block for one shard of a manifest."""
    per = manifest.get("shards")
    if per is not None:
        blk = per.get(str(shard_id))
        if blk is None:
            raise FileNotFoundError(
                f"checkpoint {where}: no shard {shard_id} in manifest "
                f"(has {sorted(per)})"
            )
        return blk
    if shard_id != 0:  # pre-§14 manifest: flat fields, single shard
        raise FileNotFoundError(
            f"checkpoint {where}: legacy single-shard manifest has no "
            f"shard {shard_id}"
        )
    return manifest


def restore_arrays(
    ckpt_dir: str, *, step: Optional[int] = None, shard_id: int = 0
) -> tuple[dict, int]:
    """Manifest-driven flat restore — no ``like`` template required.

    The recovery path has no live object to mirror (the process that
    owned the shapes is gone), so the manifest is the source of truth:
    every key must load with exactly its recorded shape and dtype.
    ``shard_id`` selects one shard file of a sharded step manifest.
    Returns ``({key: ndarray}, step)``.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    blk = _shard_manifest(manifest, int(shard_id), d)
    data = np.load(
        os.path.join(d, f"shard_{int(shard_id)}.npz"), allow_pickle=False
    )
    if set(data.files) != set(blk["keys"]):
        raise ValueError(
            f"checkpoint {d}: shard {shard_id} npz keys disagree with manifest"
        )
    out = {}
    for k in blk["keys"]:
        v = data[k]
        if list(v.shape) != blk["shapes"][k] or str(v.dtype) != blk["dtypes"][k]:
            raise ValueError(
                f"checkpoint {d}: {k} is {v.shape}/{v.dtype}, manifest says "
                f"{blk['shapes'][k]}/{blk['dtypes'][k]}"
            )
        out[k] = v
    return out, int(step)


def restore_arrays_sharded(
    ckpt_dir: str, *, step: Optional[int] = None
) -> tuple[dict, int]:
    """Restore every shard of a step: ``({shard_id: arrays}, step)``.

    Serial replay — shards load one after another (parallel replay is a
    ROADMAP item).  Legacy single-shard manifests come back as
    ``{0: arrays}``.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    sids = (
        sorted(int(s) for s in manifest["shards"])
        if manifest.get("shards") is not None
        else [0]
    )
    return (
        {s: restore_arrays(ckpt_dir, step=step, shard_id=s)[0] for s in sids},
        int(step),
    )


def clean_stale(ckpt_dir: str) -> list[str]:
    """Sweep ``.tmp_ckpt_*`` debris left by writers that died pre-commit.

    Recovery calls this first: an interrupted checkpoint never renamed
    into place, so its tmp dir is garbage by construction.
    """
    removed = []
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith(".tmp_ckpt_"):
                shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
                removed.append(name)
    return removed


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step).

    Validates manifest keys/shapes against ``like`` first — a structure
    mismatch (code drift vs checkpoint) fails loudly before any device
    allocation.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    want, treedef = _flatten_with_paths(like)
    missing = set(want) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(want)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    for k, v in want.items():
        if list(data[k].shape) != list(v.shape):
            raise ValueError(f"shape mismatch for {k}: {data[k].shape} vs {v.shape}")
    leaves_sorted = {k: data[k] for k in want}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        restored.append(
            jax.numpy.asarray(leaves_sorted[key], dtype=leaf.dtype)
            if hasattr(leaf, "dtype")
            else leaves_sorted[key]
        )
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), restored), step


def checkpoint_hook(ckpt_dir: str, every: int, *, keep: int = 3):
    """Training-loop hook: persist state every N steps."""

    def hook(step: int, state):
        if step > 0 and step % every == 0:
            save(ckpt_dir, step, state, keep=keep)
        return state

    return hook
