"""Optional compiled row parser for fixed-width MTX bodies (DESIGN.md §10).

The numpy fixed-width path costs ~8 full-matrix passes; this is the same
contract — bounds-verify every byte against the row-0 layout, fold ids
and the scientific weight — as ONE C pass over the body (~0.5ns/byte).
It is an *accelerator* in the same spirit as the Pallas kernels: built
on demand with whatever ``cc`` the host has, loaded via ctypes, and
gated so that any failure (no compiler, sandboxed exec, odd layout)
silently falls back to the numpy engine.  Bit-for-bit parity with the
numpy path is enforced by tests — both fold the mantissa in float64 and
apply the same decade table, so they round identically to float32.
"""
from __future__ import annotations

import ctypes
import os
import subprocess
import tempfile
import threading

import numpy as np

_SOURCE = r"""
#include <stdint.h>

/* Folds only — the caller has already bounds-verified every byte (the
 * numpy masked compare is SIMD and ~10x what gcc emits for the same
 * loop here; the sequential per-row folds are where C wins).  All digit
 * groups fold as independent multiply-accumulates against power tables
 * — a Horner chain (v = v*10 + d) is a serially-dependent multiply per
 * digit and measured ~3x slower. */
static const int64_t IP10[19] = {
    1LL, 10LL, 100LL, 1000LL, 10000LL, 100000LL, 1000000LL, 10000000LL,
    100000000LL, 1000000000LL, 10000000000LL, 100000000000LL,
    1000000000000LL, 10000000000000LL, 100000000000000LL,
    1000000000000000LL, 10000000000000000LL, 100000000000000000LL,
    1000000000000000000LL,
};

/* rc: 0 ok, 2 coordinate out of [1, n_limit].  flags[0] <- 1 when the
 * (src, dst) stream is already lexicographically sorted (CSR order). */
int parse_fixed_rows(
    const uint8_t* restrict body, int64_t nnz, int32_t w,
    int32_t a0, int32_t b0, int32_t a1, int32_t b1,
    int32_t mstart, int32_t mdot, int32_t mend,
    int32_t estart, int32_t eend, int32_t esign_col, int32_t neg_col,
    const double* restrict p10e, int32_t e_bias, int64_t n_limit,
    int64_t* restrict src, int64_t* restrict dst, float* restrict wgt,
    int32_t* restrict flags)
{
    uint64_t nl = (uint64_t)n_limit;
    uint64_t oob = 0, prev_key = 0;
    int32_t sorted = 1;
    /* per-column powers of the mantissa (dot-aware), hoisted once */
    int64_t mpw[80];
    int32_t frac = 0;
    if (mstart >= 0) {
        int32_t nd = 0;
        for (int32_t j = mend - 1; j >= mstart; --j) {
            if (j == mdot) { mpw[j - mstart] = 0; continue; }
            mpw[j - mstart] = IP10[nd < 19 ? nd : 18];
            nd++;
            if (mdot >= 0 && j > mdot) frac++;
        }
    }
    /* two rows per iteration: each row's folds are a serial add chain,
     * so pairing rows gives the OoO core two independent chains */
    int64_t r = 0;
    for (; r + 2 <= nnz; r += 2) {
        const uint8_t* restrict ra = body + (int64_t)r * w;
        const uint8_t* restrict rb = ra + w;
        int64_t sa = 0, da = 0, sb = 0, db = 0;
        for (int32_t j = a0; j < b0; ++j) {
            sa += (int64_t)(ra[j] - '0') * IP10[b0 - 1 - j];
            sb += (int64_t)(rb[j] - '0') * IP10[b0 - 1 - j];
        }
        for (int32_t j = a1; j < b1; ++j) {
            da += (int64_t)(ra[j] - '0') * IP10[b1 - 1 - j];
            db += (int64_t)(rb[j] - '0') * IP10[b1 - 1 - j];
        }
        src[r] = sa; src[r + 1] = sb;
        dst[r] = da; dst[r + 1] = db;
        oob |= ((uint64_t)(sa - 1) >= nl) | ((uint64_t)(da - 1) >= nl)
             | ((uint64_t)(sb - 1) >= nl) | ((uint64_t)(db - 1) >= nl);
        uint64_t ka = ((uint64_t)sa << 32) | (uint64_t)da;
        uint64_t kb = ((uint64_t)sb << 32) | (uint64_t)db;
        sorted &= (ka >= prev_key) & (kb >= ka);
        prev_key = kb;
        if (mstart >= 0) {
            int64_t ma = 0, mb = 0;
            for (int32_t j = mstart; j < mend; ++j) {
                ma += (int64_t)(ra[j] - '0') * mpw[j - mstart];
                mb += (int64_t)(rb[j] - '0') * mpw[j - mstart];
            }
            /* the dot column's power is 0, so its byte contributes 0 */
            int32_t ea = 0, eb = 0;
            for (int32_t j = estart; j < eend; ++j) {
                ea += (int32_t)(ra[j] - '0') * (int32_t)IP10[eend - 1 - j];
                eb += (int32_t)(rb[j] - '0') * (int32_t)IP10[eend - 1 - j];
            }
            if (esign_col >= 0 && ra[esign_col] == '-') ea = -ea;
            if (esign_col >= 0 && rb[esign_col] == '-') eb = -eb;
            int32_t ka = ea - frac + e_bias;
            int32_t kb = eb - frac + e_bias;
            if (ka < 0) ka = 0;
            if (ka > 2 * e_bias) ka = 2 * e_bias;
            if (kb < 0) kb = 0;
            if (kb > 2 * e_bias) kb = 2 * e_bias;
            double va = (double)ma * p10e[ka];
            double vb = (double)mb * p10e[kb];
            wgt[r] = (float)(neg_col >= 0 && ra[neg_col] == '-' ? -va : va);
            wgt[r + 1] =
                (float)(neg_col >= 0 && rb[neg_col] == '-' ? -vb : vb);
        }
    }
    for (; r < nnz; ++r) {
        const uint8_t* restrict row = body + (int64_t)r * w;
        int64_t s = 0, d = 0;
        for (int32_t j = a0; j < b0; ++j)
            s += (int64_t)(row[j] - '0') * IP10[b0 - 1 - j];
        for (int32_t j = a1; j < b1; ++j)
            d += (int64_t)(row[j] - '0') * IP10[b1 - 1 - j];
        src[r] = s;
        dst[r] = d;
        oob |= ((uint64_t)(s - 1) >= nl) | ((uint64_t)(d - 1) >= nl);
        uint64_t key = ((uint64_t)s << 32) | (uint64_t)d;
        sorted &= (key >= prev_key);
        prev_key = key;
        if (mstart >= 0) {
            int64_t mi = 0;
            for (int32_t j = mstart; j < mend; ++j)
                mi += (int64_t)(row[j] - '0') * mpw[j - mstart];
            int32_t e = 0;
            for (int32_t j = estart; j < eend; ++j)
                e += (int32_t)(row[j] - '0') * (int32_t)IP10[eend - 1 - j];
            if (esign_col >= 0 && row[esign_col] == '-') e = -e;
            int32_t k = e - frac + e_bias;
            if (k < 0) k = 0;
            if (k > 2 * e_bias) k = 2 * e_bias;
            double v = (double)mi * p10e[k];
            wgt[r] = (float)(neg_col >= 0 && row[neg_col] == '-' ? -v : v);
        }
    }
    flags[0] = sorted;
    return oob ? 2 : 0;
}
"""

_lock = threading.Lock()
_lib = None
_failed = False


def _cache_so_path() -> str:
    """Stable per-user cache keyed by source hash: one compile EVER per
    parser version (not per process), and nothing accumulates in /tmp."""
    import hashlib

    h = hashlib.sha256(_SOURCE.encode()).hexdigest()[:16]
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    d = os.path.join(base, "repro_cparse")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"cparse_{h}.so")


def _build():
    """Compile the parser with the host cc; any failure disables it."""
    try:
        so = _cache_so_path()
    except OSError:
        so = os.path.join(
            tempfile.mkdtemp(prefix="repro_cparse_"), "cparse.so"
        )
    if not os.path.exists(so):
        _compile(so)
    if os.path.exists(so):
        return _load(so)
    return None


def _compile(so: str) -> None:
    build_dir = tempfile.mkdtemp(prefix="repro_cparse_build_")
    src = os.path.join(build_dir, "cparse.c")
    tmp_so = os.path.join(build_dir, "cparse.so")
    with open(src, "w") as f:
        f.write(_SOURCE)
    attempts = [
        [cc, "-O3", *extra, "-shared", "-fPIC", "-o", tmp_so, src]
        for cc in ("cc", "gcc", "clang")
        for extra in (["-march=native"], [])
    ]
    try:
        for cmd in attempts:
            try:
                r = subprocess.run(cmd, capture_output=True, timeout=60)
            except (OSError, subprocess.TimeoutExpired):
                continue
            if r.returncode == 0 and os.path.exists(tmp_so):
                os.replace(tmp_so, so)  # atomic vs concurrent builders
                return
    finally:
        import shutil

        shutil.rmtree(build_dir, ignore_errors=True)


def _load(so: str):
    try:
        lib = ctypes.CDLL(so)
    except OSError:
        return None
    fn = lib.parse_fixed_rows
    fn.restype = ctypes.c_int
    fn.argtypes = [
        ctypes.c_void_p, ctypes.c_int64, ctypes.c_int32,
        ctypes.c_int32, ctypes.c_int32, ctypes.c_int32, ctypes.c_int32,
        ctypes.c_int32, ctypes.c_int32, ctypes.c_int32,
        ctypes.c_int32, ctypes.c_int32, ctypes.c_int32, ctypes.c_int32,
        ctypes.c_void_p, ctypes.c_int32, ctypes.c_int64,
        ctypes.c_void_p, ctypes.c_void_p, ctypes.c_void_p,
        ctypes.c_void_p,
    ]
    return fn


def available() -> bool:
    global _lib, _failed
    if _lib is not None:
        return True
    if _failed:
        return False
    with _lock:
        if _lib is None and not _failed:
            try:
                _lib = _build()
            except Exception:
                _lib = None
            if _lib is None:
                _failed = True
    return _lib is not None


def parse_fixed_rows(body, nnz, w, ints, flt, p10e, e_bias, n_limit):
    """Per-row digit folds (bytes must already be bounds-verified).

    ``ints`` = ((a0, b0), (a1, b1)) digit column ranges of the id fields;
    ``flt`` = (mstart, mdot, mend, estart, eend, esign_col, neg_col) or
    None for pattern files (every position -1 disables that feature).
    Returns (src, dst, wgt|None, presorted) or None when the parser is
    unavailable; raises ValueError on a 1-based id outside [1, n_limit].
    """
    if not available():
        return None
    body = np.ascontiguousarray(body)
    src = np.empty(nnz, np.int64)
    dst = np.empty(nnz, np.int64)
    flags = np.zeros(1, np.int32)
    if flt is None:
        mstart = mdot = mend = estart = eend = esign_col = neg_col = -1
        wgt = np.empty(1, np.float32)
    else:
        mstart, mdot, mend, estart, eend, esign_col, neg_col = flt
        wgt = np.empty(nnz, np.float32)
    rc = _lib(
        body.ctypes.data, nnz, w,
        ints[0][0], ints[0][1], ints[1][0], ints[1][1],
        mstart, mdot, mend, estart, eend, esign_col, neg_col,
        p10e.ctypes.data, e_bias, n_limit,
        src.ctypes.data, dst.ctypes.data, wgt.ctypes.data,
        flags.ctypes.data,
    )
    if rc == 2:
        raise ValueError("malformed MTX body: coordinate out of range")
    if rc != 0:
        return None
    return src, dst, (wgt if flt is not None else None), bool(flags[0])
