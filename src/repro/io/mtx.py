"""MTX ingest engine (paper Algorithms 3–5, DESIGN.md §10).

The paper's loader wins by (a) block-partitioned parallel byte parsing,
(b) per-partition degree counting, (c) shifted-offset CSR fill with no
post-processing pass.  The seed approximated (a) with ~40 numpy passes of
per-digit cursor advancement and paid an O(M log M) host ``np.lexsort``
for (c).  This module is the rebuilt pipeline:

  tokenize   ONE separator-mask pass (``byte > 32``) + shift gives every
             token's [start, end) span; token *count* is validated
             against the header's nnz so truncated or malformed bodies
             raise instead of silently loading a partial graph.
  parse      each field column becomes a small [T, L] byte matrix whose
             digits are folded with one table-gathered power-of-10
             multiply — a constant ~10 vectorized passes total, no
             python per line OR per digit.  Files written by our own
             ``write_mtx`` hit a *fixed-width fast path*: uniform line
             length is detected, the body reshapes to [nnz, W], and
             fields parse as contiguous column slices with zero gathers.
  build      ``kernels/csr_build`` replaces the host lexsort with a
             counting-sort build (packed-key radix argsort off-TPU, a
             fused lax.sort + scatter program on TPU) and can emit the
             DiGraph arena image directly (``load_digraph``).

Files larger than ``mmap_threshold`` stream through ``np.memmap`` in
newline-aligned chunks, so ingest never materializes the file in RAM.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Optional

import numpy as np

from ..core import csr as csr_mod
from . import _cparse

#: gate for the optional compiled row parser (see io/_cparse.py); the
#: numpy engine below is the always-available reference implementation
USE_C_PARSE = True

_NL = 10  # \n

# power tables: P10I[k] = 10^k (int64), P10F[k] = 10^k (f64) with a zero
# guard slot at index GUARD for masked (non-digit) cells, REP*[k] = the
# repunit 1+10+...+10^(k-1) used to fold the ASCII '0' bias out of a
# digit-matrix dot product in one step.
_GUARD = 20
_P10I = np.zeros(_GUARD + 1, np.int64)
_P10I[:19] = 10 ** np.arange(19, dtype=np.int64)
_P10F = np.zeros(_GUARD + 1, np.float64)
_P10F[:19] = 10.0 ** np.arange(19)
_REPI = np.cumsum(np.concatenate([[0], _P10I[:19]])).astype(np.int64)
_REPF = _REPI.astype(np.float64)
# full-range f64 decade table for applying decimal exponents (underflows
# to 0.0 below ~1e-323, overflows to inf above 1e308 — matching strtod)
_E_BIAS = 350
with np.errstate(over="ignore"):
    _P10E = np.power(10.0, np.arange(-_E_BIAS, _E_BIAS + 1))


@dataclasses.dataclass
class MtxHeader:
    symmetric: bool
    weighted: bool
    rows: int
    cols: int
    nnz: int
    header_end: int  # byte offset where data lines start

    @property
    def n_fields(self) -> int:
        return 3 if self.weighted else 2


def read_header(buf: bytes) -> MtxHeader:
    """readHeader() of Alg 3."""
    pos = 0
    first = buf[: buf.index(b"\n")].decode()
    if not first.startswith("%%MatrixMarket"):
        raise ValueError("not an MTX file")
    toks = first.lower().split()
    weighted = "pattern" not in toks
    symmetric = "symmetric" in toks
    # skip comment lines
    while True:
        end = buf.index(b"\n", pos)
        line = buf[pos : end + 1]
        if not line.startswith(b"%"):
            break
        pos = end + 1
    dims = buf[pos : buf.index(b"\n", pos)].split()
    if len(dims) < 3:
        raise ValueError("malformed MTX size line")
    rows, cols, nnz = int(dims[0]), int(dims[1]), int(dims[2])
    header_end = buf.index(b"\n", pos) + 1
    return MtxHeader(symmetric, weighted, rows, cols, nnz, header_end)


# ---------------------------------------------------------------------------
# tokenizer — one separator-mask pass over the byte buffer
# ---------------------------------------------------------------------------
def _token_spans(body: np.ndarray):
    """Token [start, end) spans: every byte > 32 is token material."""
    num = body > 32
    ts = num.copy()
    ts[1:] &= ~num[:-1]
    te = num.copy()
    te[:-1] &= ~num[1:]
    starts = np.flatnonzero(ts)
    lens = np.flatnonzero(te) + 1 - starts
    return starts, lens


def _field_matrix(body: np.ndarray, starts: np.ndarray, lens: np.ndarray):
    """Gather token bytes into a [T, L] matrix (L = longest token).

    Index math runs in int32 when the body allows it (halves the traffic
    of every positional pass downstream) and falls back to int64 for
    bodies >= 2 GiB fed in as one buffer.
    """
    t = starts.shape[0]
    lmax = int(lens.max()) if t else 1
    if lmax > 32:
        raise ValueError("malformed MTX body: token longer than 32 bytes")
    idt = np.int32 if body.shape[0] + 33 < 2**31 else np.int64
    lane = np.arange(lmax, dtype=idt)
    idx = starts.astype(idt)[:, None] + lane
    np.minimum(idx, idt(body.shape[0] - 1), out=idx)
    mat = body[idx]
    inrow = lane < lens.astype(idt)[:, None]
    return mat, inrow, lane


def _parse_int_tokens(body, starts, lens) -> np.ndarray:
    """Vectorized atoi of T tokens -> int64 (digits only; MTX coordinates)."""
    if starts.shape[0] == 0:
        return np.zeros(0, np.int64)
    mat, inrow, lane = _field_matrix(body, starts, lens)
    if not (((mat - np.uint8(48)) < 10) | ~inrow).all():
        raise ValueError("malformed MTX body: non-digit byte in index field")
    if int(lens.max()) > 19:
        raise ValueError("malformed MTX body: integer field overflows int64")
    # an all-digit token's byte at lane j weighs 10^(len-1-j); the repunit
    # correction removes the ASCII '0' bias in the same dot product.
    # Beyond-token lanes clip to -1, which wraps to the table's 0 guard.
    l32 = lens.astype(np.int32)
    w = _P10I[np.clip(l32[:, None] - 1 - lane, -1, 19)]
    return (mat * w).sum(axis=1) - 48 * _REPI[np.minimum(lens, 19)]


def _parse_float_tokens(body, starts, lens) -> np.ndarray:
    """Vectorized strtod of T tokens -> f64 (sign, '.', e/E exponents).

    A well-formed number is ``[sign] digits [. digits] [e [sign] digits]``
    so every digit's rank is *positional arithmetic* — no per-row cumsum
    (numpy's axis-1 cumsum costs more than the rest of the parse
    combined).  Structure bytes are located with argmax, digit weights
    come from one power-table gather, and the whole mantissa folds in a
    single masked dot product.
    """
    if starts.shape[0] == 0:
        return np.zeros(0, np.float64)
    mat, inrow, lane = _field_matrix(body, starts, lens)
    lmax = mat.shape[1]
    isd = ((mat - np.uint8(48)) < 10) & inrow
    ise = ((mat == 101) | (mat == 69)) & inrow
    isdot = (mat == 46) & inrow
    issign = ((mat == 45) | (mat == 43)) & inrow
    if not (isd | ise | isdot | issign | ~inrow).all():
        raise ValueError("malformed MTX body: bad byte in value field")
    l32 = lens.astype(np.int32)
    has_e = ise.any(axis=1)
    epos = np.where(has_e, ise.argmax(axis=1).astype(np.int32), l32)
    hasdot = isdot.any(axis=1)
    dotpos = np.where(hasdot, isdot.argmax(axis=1).astype(np.int32), lmax)
    sgn = issign[:, 0].astype(np.int32)                  # leading sign byte?
    # exponent-part sign byte sits right after 'e'
    es_b = np.take_along_axis(
        mat, np.minimum(epos + 1, lmax - 1)[:, None], axis=1
    )[:, 0]
    esgn = (has_e & ((es_b == 45) | (es_b == 43))).astype(np.int32)
    # structural validation: one dot before 'e', signs only in slot 0 or
    # after 'e', at least one digit on each side
    cntm = epos - sgn - hasdot
    cnte = np.where(has_e, l32 - epos - 1 - esgn, 0)
    sign_ok = issign.copy()
    sign_ok[:, 0] = False
    # only rows WITH an exponent get their e-sign lane cleared; rows
    # without one point at lane 0 (already cleared), so a trailing sign
    # byte on a max-length no-exponent token still flags as malformed
    np.put_along_axis(
        sign_ok,
        np.where(has_e, np.minimum(epos + 1, lmax - 1), 0)[:, None],
        False,
        axis=1,
    )
    if (
        (isdot.sum(axis=1) > 1).any()
        or sign_ok.any()
        or (cntm <= 0).any()
        or (has_e & (cnte <= 0)).any()
        or (hasdot & (dotpos > epos)).any()
        or int(cntm.max(initial=0)) > 19
        or int(cnte.max(initial=0)) > 18
    ):
        raise ValueError("malformed MTX body: unparseable value field")
    # mantissa fold with NO 2-D masking: digit at lane j weighs
    # 10^(cntm-1+sgn - j + (j > dotpos)); lanes past the mantissa go
    # negative and clip to the table's 0 guard.  The sign and dot bytes
    # do pick up a weight — their known contributions are subtracted as
    # per-row scalars afterwards, which is far cheaper than masking every
    # cell of the matrix.
    expo = (cntm - 1 + sgn)[:, None] - lane + (lane > dotpos[:, None])
    d_val = (mat * _P10F[np.clip(expo, -1, 19)]).sum(axis=1)
    frac = np.where(hasdot, epos - dotpos - 1, 0)
    d_val -= 48.0 * _REPF[cntm]                           # ASCII digit bias
    d_val -= np.where(hasdot, 46.0 * _P10F[np.clip(frac - 1, -1, 19)], 0.0)
    d_val -= np.where(
        sgn > 0, mat[:, 0] * _P10F[np.clip(cntm, 0, 19)], 0.0
    )
    exp10 = (-frac).astype(np.int64)
    if has_e.any():
        # exponent fold: weight 10^(cnte + epos + esgn - j) right of the
        # sign byte; everything at or left of it is masked (mantissa
        # lanes would otherwise alias into small positive exponents)
        expo_e = (cnte + epos + esgn)[:, None] - lane
        w_e = _P10I[np.clip(expo_e, -1, 19)]
        w_e *= lane > (epos + esgn)[:, None]
        e_val = (mat * w_e).sum(axis=1) - 48 * _REPI[cnte]
        exp10 += np.where(es_b == 45, -e_val, e_val)
    neg = mat[:, 0] == 45
    scale = _P10E[np.clip(exp10 + _E_BIAS, 0, 2 * _E_BIAS)]
    return np.where(neg, -d_val, d_val) * scale


# ---------------------------------------------------------------------------
# fixed-width fast path (files written by our write_mtx, or any aligned
# writer): the body reshapes to [nnz, W] and fields are column slices
# ---------------------------------------------------------------------------
#: reusable scratch buffers (pow-2 row bucketed, thread-local so the
#: partition-parallel parse never shares one) — the fixed-path parser
#: runs hot in benchmarks and loaders; re-mmapping multi-MB temporaries
#: on every call costs more in page faults than the arithmetic itself
_scratch_tls = threading.local()


def _scratch(tag: str, shape: tuple, dtype) -> np.ndarray:
    cache = getattr(_scratch_tls, "cache", None)
    if cache is None:
        cache = _scratch_tls.cache = {}
    rows = 1 << max(int(shape[0]) - 1, 1).bit_length()
    key = (tag, rows, shape[1:], np.dtype(dtype).str)
    buf = cache.get(key)
    if buf is None:
        buf = cache[key] = np.empty((rows,) + tuple(shape[1:]), dtype)
    return buf[: shape[0]]


class _Fields(list):
    """Parsed field columns + provenance flags from the compiled path.

    ``validated``: ids already range-checked against the header dims;
    ``presorted``: the (src, dst) stream was observed in CSR order.
    """

    validated = False
    presorted = None


def _digit_chunks(cols: list[int]):
    """Split a digit-column group into f32-exact dot-product chunks.

    A chunk of <= 6 decimal digits keeps every partial sum of the fold
    below 2^24 (raw ASCII bytes <= 57 x repunit(6) ~ 6.3e6), so its dot
    product with a power vector is exact in float32 — which lets ALL
    digit groups of a fixed-width file fold through ONE sgemm.  Returns
    [(cols, scale10)] where the chunk contributes value * 10^scale10
    (before the ASCII '0' bias is removed).
    """
    out = []
    k = len(cols)
    pos = 0
    while pos < k:
        take = min(6, k - pos)
        out.append((cols[pos : pos + take], k - pos - take))
        pos += take
    return out


def _parse_fixed(body: np.ndarray, nnz: int, n_fields: int,
                 n_limit: Optional[int] = None):
    """Column-sliced parse of a uniform-width body; None when not fixed.

    Layout is derived from row 0, then verified for EVERY row with one
    per-column min/max pass: digit columns must stay in '0'..'9',
    structural columns (separators, '.', 'e', newline) must be constant,
    and sign columns must stay in {' ', '-'} / {'+', '-'}.  Any mismatch
    (ragged ids, shifting layouts) falls back to the general tokenizer.
    All digit folding then happens in a single [nnz, W] @ [W, C] sgemm.
    """
    size = body.shape[0]
    if nnz == 0 or size % nnz:
        return None
    w = size // nnz
    if w < 2 * n_fields or w > 80 or body[w - 1] != _NL:
        return None
    if not (body[w - 1 :: w] == _NL).all():
        return None
    mat = body[: nnz * w].reshape(nnz, w)
    row0 = body[:w]
    spans, t0 = [], None
    for j in range(w):
        if row0[j] > 32 and t0 is None:
            t0 = j
        elif row0[j] <= 32 and t0 is not None:
            spans.append((t0, j))
            t0 = None
    if len(spans) != n_fields:
        return None

    # column classification (from row 0)
    digit_cols: set[int] = set()
    fields = []  # per field: list of (chunk_cols, scale)
    sign_cols: list[int] = []
    esign_col = frac = None
    e_cols: list[int] = []
    neg_col = None
    flt_layout = None  # (mstart, mdot, mend) for the compiled path
    for f, (a, b) in enumerate(spans):
        cols = list(range(a, b))
        if f < 2:
            digit_cols.update(cols)
            fields.append(_digit_chunks(cols))
            continue
        # float field: [sign] d [. ddd] [e [sign] dd]
        if row0[a] == 45:
            neg_col, a = a, a + 1
        elif a > 0:
            neg_col = a - 1
            sign_cols.append(neg_col)
        rel = body[a:b]
        e_at = np.flatnonzero((rel == 101) | (rel == 69))
        if e_at.shape[0] > 1:
            return None
        e_pos = a + int(e_at[0]) if e_at.shape[0] else b
        dot_at = np.flatnonzero(rel[: e_pos - a] == 46)
        if dot_at.shape[0] > 1:
            return None
        dot_pos = a + int(dot_at[0]) if dot_at.shape[0] else e_pos
        mant = [j for j in range(a, e_pos) if j != dot_pos]
        if not mant:
            return None
        digit_cols.update(mant)
        frac = e_pos - dot_pos - 1 if dot_at.shape[0] else 0
        flt_layout = (a, dot_pos, e_pos)
        fields.append(_digit_chunks(mant))
        if e_at.shape[0]:
            es = e_pos + 1
            if es >= b:
                return None
            if row0[es] in (43, 45):
                esign_col = es
                es += 1
            e_cols = list(range(es, b))
            if not e_cols or len(e_cols) > 18:
                return None
            digit_cols.update(e_cols)

    # one whole-matrix bounds pass verifies every row against the row-0
    # layout: digit columns stay in '0'..'9', structural columns constant.
    # (mat - lo) > span with uint8 wraparound is a single masked compare.
    lo = row0.copy()
    span = np.zeros(w, np.uint8)
    for j in digit_cols:
        lo[j], span[j] = 48, 9
    free = [j for j in range(w) if j == neg_col or j in sign_cols
            or j == esign_col]
    for j in free:
        lo[j], span[j] = 0, 255  # two-valued columns checked below
    # flat tiled bounds: broadcasting [nnz, w] against [w] runs one
    # 26-byte SIMD stanza per row (all overhead); tiling lo/span to the
    # full body length (cached per layout) makes each pass ONE long
    # vector op
    nb = nnz * w
    cache = getattr(_scratch_tls, "cache", None)
    if cache is None:
        cache = _scratch_tls.cache = {}
    lkey = (w, lo.tobytes(), span.tobytes())
    if cache.get("bounds_layout") != lkey or cache["bounds_lo"].shape[0] < nb:
        reps = -(-max(nb, 1) // w)
        cache["bounds_lo"] = np.tile(lo, reps)
        cache["bounds_span"] = np.tile(span, reps)
        cache["bounds_layout"] = lkey
    flat = mat.reshape(-1)
    rs = _scratch("resid", (nb,), np.uint8)
    viol = _scratch("viol", (nb,), bool)
    np.subtract(flat, cache["bounds_lo"][:nb], out=rs)
    np.greater(rs, cache["bounds_span"][:nb], out=viol)
    if viol.any():
        return None
    m1 = _scratch("free_m1", (nnz,), bool)
    m2 = _scratch("free_m2", (nnz,), bool)
    for j in free:
        col = mat[:, j]
        allowed = (43, 45) if j == esign_col else (32, 45)
        np.equal(col, allowed[0], out=m1)
        np.equal(col, allowed[1], out=m2)
        np.logical_or(m1, m2, out=m1)
        if not m1.all():
            return None

    # every byte is now verified; the folds run through the compiled
    # row parser when available (numpy does the SIMD-friendly masked
    # compare above, C does the sequential per-row Horner folds — each
    # side doing what it is fastest at), with the sgemm formulation
    # below as the always-available fallback
    if (
        USE_C_PARSE
        and n_limit is not None
        and spans[0][1] - spans[0][0] <= 18
        and spans[1][1] - spans[1][0] <= 18
        and (
            flt_layout is None
            or flt_layout[2] - flt_layout[0] <= 16  # f64-exact mantissa
        )
    ):
        flt = None
        if flt_layout is not None:
            mstart, mdot, mend = flt_layout
            estart, eend = (e_cols[0], e_cols[-1] + 1) if e_cols else (-1, -1)
            flt = (
                mstart, mdot, mend, estart, eend,
                -1 if esign_col is None else esign_col,
                -1 if neg_col is None else neg_col,
            )
        got = _cparse.parse_fixed_rows(
            mat, nnz, w, (spans[0], spans[1]), flt, _P10E, _E_BIAS,
            int(n_limit),
        )
        if got is not None:
            src_c, dst_c, wgt_c, presorted = got
            out = _Fields(
                [src_c, dst_c] + ([wgt_c] if wgt_c is not None else [])
            )
            out.validated = True
            out.presorted = presorted
            return out

    # fold every digit chunk — exponent digits included — with ONE sgemm
    # (f32-exact by construction, see _digit_chunks).  Whole-matrix
    # passes over reusable scratch: sequential streams prefetch well,
    # and scratch reuse (not fresh allocations) is what keeps repeat
    # loads from re-faulting pages.  (A cache-tiled variant was tried
    # and lost — per-tile BLAS dispatch overhead exceeded the DRAM
    # traffic it saved.)
    chunk_list = [c for fchunks in fields for c in fchunks]
    e_chunks = _digit_chunks(e_cols) if e_cols else []
    chunk_list += e_chunks
    wmat = np.zeros((w, len(chunk_list)), np.float32)
    for ci, (cols, _) in enumerate(chunk_list):
        k = len(cols)
        wmat[cols, ci] = 10.0 ** np.arange(k - 1, -1, -1, dtype=np.float32)
    mt = _scratch("matf", (nnz, w), np.float32)
    np.copyto(mt, mat, casting="unsafe")
    folded = np.matmul(
        mt, wmat, out=_scratch("folded", (nnz, len(chunk_list)), np.float32)
    )

    # scalar tail: every [nnz]-sized intermediate lives in scratch and
    # every op writes in place — only the three returned arrays allocate
    # (fresh multi-hundred-KB temporaries re-fault pages on every call
    # once other loaders have churned the allocator)
    def fold_into(fchunks, base, out64):
        # chunks combine as Σ chunk_i · 10^s_i; the per-chunk ASCII '0'
        # biases (48 · repunit) collapse into ONE constant subtracted at
        # the end, so an f-field folds in len(chunks)+1 passes
        bias = 0.0
        for off, (cols, scale) in enumerate(fchunks):
            col = folded[:, base + off]
            bias += 48.0 * float(_REPF[len(cols)]) * float(_P10F[scale])
            # np.float64 scalars force the f64 ufunc loop — a bare python
            # float is NEP-50-weak and would fold the >2^24 digit values
            # in f32
            if off == 0:
                if scale:
                    np.multiply(col, np.float64(_P10F[scale]), out=out64)
                else:
                    np.copyto(out64, col)
            elif scale:
                tmp = _scratch("fold_tmp", (nnz,), np.float64)
                np.multiply(col, np.float64(_P10F[scale]), out=tmp)
                np.add(out64, tmp, out=out64)
            else:
                np.add(out64, col, out=out64)
        if bias:
            np.subtract(out64, np.float64(bias), out=out64)
        return out64

    out = []
    ci = 0
    val = _scratch("fold_val", (nnz,), np.float64)
    mask = _scratch("fold_mask", (nnz,), bool)
    for f, fchunks in enumerate(fields):
        fold_into(fchunks, ci, val)
        ci += len(fchunks)
        if f < 2:
            ints = np.empty(nnz, np.int64)
            np.copyto(ints, val, casting="unsafe")
            out.append(ints)
            continue
        if neg_col is not None:
            np.equal(mat[:, neg_col], 45, out=mask)
            np.negative(val, out=val, where=mask)
        if e_chunks:
            e_val = _scratch("fold_eval", (nnz,), np.float64)
            fold_into(e_chunks, ci, e_val)
            if esign_col is not None:
                np.equal(mat[:, esign_col], 45, out=mask)
                np.negative(e_val, out=e_val, where=mask)
            # decade lookup: exp10 = e_val - frac, biased into the table
            np.add(e_val, float(_E_BIAS - frac), out=e_val)
            np.clip(e_val, 0, 2 * _E_BIAS, out=e_val)
            idx = _scratch("fold_idx", (nnz,), np.int64)
            np.copyto(idx, e_val, casting="unsafe")
            scale64 = _scratch("fold_scale", (nnz,), np.float64)
            np.take(_P10E, idx, out=scale64)
            np.multiply(val, scale64, out=val)
        else:
            np.multiply(val, float(_P10E[_E_BIAS - frac]), out=val)
        # emit float32 directly — the CSR weight dtype — halving the
        # output traffic and sparing the assemble-stage astype
        res = np.empty(nnz, np.float32)
        np.copyto(res, val, casting="unsafe")
        out.append(res)
    return out


# ---------------------------------------------------------------------------
# edgelist assembly (Alg 4)
# ---------------------------------------------------------------------------
#: bodies below this size parse single-threaded.  The partition fan-out
#: is the paper's Alg 4 structure and wins on real multi-core hosts, but
#: on this container's 2 shared vCPUs it loses to dispatch overhead at
#: every size measured (0.9MB-6.4MB), so the gate sits above the bench
#: graphs; tests force it down to exercise the path.
_PARALLEL_MIN_BYTES = 1 << 25
_pool = None


def _parse_pool():
    global _pool
    if _pool is None:
        from concurrent.futures import ThreadPoolExecutor

        _pool = ThreadPoolExecutor(max_workers=os.cpu_count() or 1)
    return _pool


def _parse_body(body: np.ndarray, n_fields: int, *, fixed: bool = True,
                nnz_hint: Optional[int] = None, num_partitions: int = 1,
                n_limit: Optional[int] = None):
    """Parse one newline-complete body slice -> list of n_fields columns.

    ``num_partitions`` > 1 block-partitions the byte buffer and parses
    the partitions on a thread pool — the paper's Alg 4 parallel parse;
    numpy releases the GIL inside every pass, so partitions genuinely
    overlap.  Fixed-width bodies split at exact row boundaries, general
    bodies at the nearest newline.
    """
    rho = min(max(int(num_partitions), 1), os.cpu_count() or 1)
    if rho > 1 and body.shape[0] >= _PARALLEL_MIN_BYTES:
        chunks = _partition_body(body, rho, nnz_hint)
        if len(chunks) > 1:
            futs = [
                _parse_pool().submit(
                    _parse_body, body[a:b], n_fields,
                    fixed=fixed, nnz_hint=rows, num_partitions=1,
                    n_limit=n_limit,
                )
                for a, b, rows in chunks
            ]
            parts = [f.result() for f in futs]
            out = _Fields(
                np.concatenate([p[f] for p in parts])
                for f in range(n_fields)
            )
            out.validated = all(
                getattr(p, "validated", False) for p in parts
            )
            return out
    if fixed and nnz_hint:
        got = _parse_fixed(body, nnz_hint, n_fields, n_limit)
        if got is not None:
            return got
    starts, lens = _token_spans(body)
    t = starts.shape[0]
    if t % n_fields:
        raise ValueError(
            f"malformed MTX body: {t} tokens is not a multiple of "
            f"{n_fields} fields"
        )
    rows = t // n_fields
    smat = starts.reshape(rows, n_fields)
    lmat = lens.reshape(rows, n_fields)
    # both index fields parse as one token batch (halves the pass count)
    ii = _parse_int_tokens(
        body,
        np.ascontiguousarray(smat[:, :2]).reshape(-1),
        np.ascontiguousarray(lmat[:, :2]).reshape(-1),
    ).reshape(rows, 2)
    out = [ii[:, 0], ii[:, 1]]
    if n_fields == 3:
        out.append(
            np.ascontiguousarray(
                _parse_float_tokens(
                    body,
                    np.ascontiguousarray(smat[:, 2]),
                    np.ascontiguousarray(lmat[:, 2]),
                )
            )
        )
    return out


def _partition_body(body: np.ndarray, rho: int, nnz_hint: Optional[int]):
    """Split a body into <= rho newline-aligned (start, end, rows) chunks."""
    size = body.shape[0]
    if nnz_hint and size % nnz_hint == 0:
        w = size // nnz_hint
        if w >= 2 and (body[w - 1 :: w] == _NL).all():
            # fixed-width: split at exact row boundaries
            rpc = -(-nnz_hint // rho)
            return [
                (i * rpc * w, min((i + 1) * rpc, nnz_hint) * w,
                 min((i + 1) * rpc, nnz_hint) - i * rpc)
                for i in range(rho)
                if i * rpc < nnz_hint
            ]
    out = []
    pos = 0
    step = -(-size // rho)
    while pos < size:
        end = min(pos + step, size)
        if end < size:
            nl = np.flatnonzero(body[end - 1 : min(end + (1 << 16), size)] == _NL)
            if nl.shape[0] == 0:
                end = size
            else:
                end = end + int(nl[0])
        out.append((pos, end, None))
        pos = end
    return out


def parse_edgelist(buf, header: MtxHeader, *, fixed: bool = True,
                   num_partitions: int = 1):
    """readEdgelist() of Alg 4, vectorized; validates the line count."""
    return _parse_edgelist_full(
        buf, header, fixed=fixed, num_partitions=num_partitions
    )[:3]


def _parse_edgelist_full(buf, header: MtxHeader, *, fixed: bool = True,
                         num_partitions: int = 1):
    """parse_edgelist + the compiled path's presorted observation."""
    data = np.frombuffer(buf, dtype=np.uint8)
    body = data[header.header_end :]
    fields = _parse_body(
        body, header.n_fields, fixed=fixed, nnz_hint=header.nnz,
        num_partitions=num_partitions,
        n_limit=max(header.rows, header.cols),
    )
    if fields[0].shape[0] != header.nnz:
        raise ValueError(
            f"truncated MTX body: header promises {header.nnz} entries, "
            f"parsed {fields[0].shape[0]}"
        )
    return _assemble_edges(fields, header)


def _assemble_edges(fields, header: MtxHeader):
    # 1-based -> 0-based (Alg 4 line 20); the parsed arrays are owned by
    # this call, so the shift happens in place
    src, dst = fields[0], fields[1]
    np.subtract(src, 1, out=src)
    np.subtract(dst, 1, out=dst)
    n = max(header.rows, header.cols)
    # the compiled fold already range-checked against the header dims
    if not getattr(fields, "validated", False) and src.shape[0] and (
        src.min(initial=0) < 0 or dst.min(initial=0) < 0
        or src.max(initial=0) >= n or dst.max(initial=0) >= n
    ):
        raise ValueError("malformed MTX body: coordinate out of range")
    if header.weighted:
        wgt = (
            fields[2]
            if fields[2].dtype == np.float32
            else fields[2].astype(np.float32)
        )
    else:
        wgt = None
    presorted = getattr(fields, "presorted", None)
    if header.symmetric:
        # Alg 4 lines 28-33: add the reverse edge
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if wgt is not None:
            wgt = np.concatenate([wgt, wgt])
        presorted = False if src.shape[0] else presorted
    return src, dst, wgt, presorted


# ---------------------------------------------------------------------------
# loadGraph() (Alg 3): header -> edgelist -> counting-sort CSR
# ---------------------------------------------------------------------------
#: files at least this large stream through np.memmap chunked parsing
MMAP_THRESHOLD = 1 << 28
#: chunk granularity of the memory-mapped reader (newline-aligned)
CHUNK_BYTES = 1 << 26


def _parse_chunked(path: str, header: MtxHeader, *, fixed: bool,
                   chunk_bytes: int, num_partitions: int = 1):
    """Parse a memory-mapped body in newline-aligned chunks.

    Uniform line width is detected from the first line so every chunk
    still takes the fixed-width fast path (with its per-chunk row count
    as the hint), and ``num_partitions`` fans each chunk out across the
    Alg-4 thread pool — huge files are exactly where both matter.
    """
    mm = np.memmap(path, dtype=np.uint8, mode="r")
    size = mm.shape[0]
    nf = header.n_fields
    # uniform-width probe: first line's width must tile the whole body
    w = 0
    if fixed:
        first_nl = np.flatnonzero(
            mm[header.header_end : min(header.header_end + 256, size)] == _NL
        )
        if first_nl.shape[0]:
            cand = int(first_nl[0]) + 1
            if (size - header.header_end) % cand == 0:
                w = cand
    parts: list[list[np.ndarray]] = []
    pos = header.header_end
    while pos < size:
        end = min(pos + chunk_bytes, size)
        if end < size:
            if w:
                end = pos + max((end - pos) // w, 1) * w  # row boundary
                end = min(end, size)
            else:
                tail = np.flatnonzero(mm[pos:end] == _NL)
                if tail.shape[0] == 0:
                    raise ValueError(
                        "malformed MTX body: line exceeds chunk size"
                    )
                end = pos + int(tail[-1]) + 1
        chunk = np.asarray(mm[pos:end])  # one chunk resident at a time
        parts.append(
            _parse_body(
                chunk, nf, fixed=fixed,
                nnz_hint=(end - pos) // w if w else None,
                num_partitions=num_partitions,
                n_limit=max(header.rows, header.cols),
            )
        )
        pos = end
    if not parts:
        return [np.zeros(0, np.int64)] * 2 + (
            [np.zeros(0, np.float64)] if nf == 3 else []
        )
    out = _Fields(
        np.concatenate([p[f] for p in parts]) for f in range(nf)
    )
    out.validated = all(getattr(p, "validated", False) for p in parts)
    return out


def load_mtx(
    path_or_bytes,
    *,
    num_partitions: int = 4,
    sort: bool = True,
    engine: str = "auto",
    fixed: bool = True,
    mmap_threshold: int = MMAP_THRESHOLD,
    chunk_bytes: int = CHUNK_BYTES,
) -> csr_mod.CSR:
    """loadGraph() of Alg 3: header -> edgelist -> partitioned CSR.

    ``engine`` selects the csr_build backend (``host`` packed-key radix
    sort off-TPU, fused ``xla`` program on TPU); ``fixed`` gates the
    fixed-width fast path; files >= ``mmap_threshold`` bytes stream
    through a memory-mapped chunked reader instead of one read().
    """
    src = dst = wgt = None
    if isinstance(path_or_bytes, bytes):
        buf = path_or_bytes
    elif isinstance(path_or_bytes, str):
        if os.path.getsize(path_or_bytes) >= mmap_threshold:
            with open(path_or_bytes, "rb") as f:
                head = f.read(1 << 20)  # header + comments live up front
            header = read_header(head)
            fields = _parse_chunked(
                path_or_bytes, header, fixed=fixed,
                chunk_bytes=chunk_bytes, num_partitions=num_partitions,
            )
            if fields[0].shape[0] != header.nnz:
                raise ValueError(
                    f"truncated MTX body: header promises {header.nnz} "
                    f"entries, parsed {fields[0].shape[0]}"
                )
            src, dst, wgt, presorted = _assemble_edges(fields, header)
        else:
            with open(path_or_bytes, "rb") as f:
                buf = f.read()
    else:
        buf = path_or_bytes.read()
    if src is None:
        header = read_header(buf)
        src, dst, wgt, presorted = _parse_edgelist_full(
            buf, header, fixed=fixed, num_partitions=num_partitions
        )
    n = max(header.rows, header.cols)
    return csr_mod.from_coo(
        src, dst, wgt, n=n, num_partitions=num_partitions,
        dedup=False, sort=sort, engine=engine, presorted=presorted,
    )


def load_digraph(path_or_bytes, **kw):
    """Fused file -> DiGraph arena load (the paper's t_load target).

    Parses, counting-sorts and builds the slotted arena image without
    materializing an intermediate device CSR.
    """
    from ..core import digraph as digraph_mod

    c = load_mtx(path_or_bytes, **kw)
    return digraph_mod.DiGraph.from_csr(c)


# ---------------------------------------------------------------------------
# writer — canonical fixed-width MTX (valid Matrix Market; the aligned
# layout is what load_mtx's fast path detects)
# ---------------------------------------------------------------------------
def _int_columns(vals: np.ndarray, width: int) -> np.ndarray:
    """Zero-padded decimal digits [T, width] (uint8 ASCII)."""
    return (
        (vals[:, None] // _P10I[width - 1 - np.arange(width)]) % 10 + 48
    ).astype(np.uint8)


def write_mtx(path: str, c: csr_mod.CSR, *, weighted: bool = True) -> None:
    """Vectorized fixed-width writer (one bytes join, no np.savetxt).

    Lines are ``SRC DST [S]D.DDDDDDDDe±EE`` with zero-padded ids and a
    9-significant-digit scientific weight (exact float32 round trip);
    every line has identical width, which both this module's fast path
    and any standards-compliant MTX reader accept.
    """
    o = np.asarray(c.offsets)
    d = np.asarray(c.dst).astype(np.int64)
    w = (
        np.asarray(c.wgt, dtype=np.float32)
        if (c.wgt is not None and weighted)
        else np.ones(c.m, np.float32)
    )
    src = np.repeat(np.arange(c.n, dtype=np.int64), np.diff(o))
    kind = "real" if weighted else "pattern"
    m = int(c.m)
    wi = max(len(str(int(c.n))), 1)
    if weighted:
        # decimal decomposition: |w| = mant * 10^e10, mant in [1, 10)
        aw = np.abs(w.astype(np.float64))
        nz = aw > 0
        e10 = np.zeros(m, np.int64)
        e10[nz] = np.floor(np.log10(aw[nz])).astype(np.int64)
        mdig = np.zeros(m, np.int64)
        mdig[nz] = np.rint(aw[nz] / _P10E[np.clip(e10[nz] + _E_BIAS, 0, 2 * _E_BIAS)] * 1e8).astype(np.int64)
        carry = mdig >= 10**9  # 9.99999999 rounded up a decade
        mdig[carry] //= 10
        e10[carry] += 1
        # SRC_wi ' ' DST_wi ' ' sign d . dddddddd e sign ee '\n'
        width = 2 * wi + 2 + 1 + 10 + 4 + 1
        out = np.full((m, width), 32, np.uint8)
        out[:, :wi] = _int_columns(src + 1, wi)
        out[:, wi + 1 : 2 * wi + 1] = _int_columns(d + 1, wi)
        p = 2 * wi + 3  # mantissa start; 2*wi+2 is the sign column
        out[:, p - 1] = np.where(w < 0, 45, 32)
        mcols = _int_columns(mdig, 9)
        out[:, p] = mcols[:, 0]
        out[:, p + 1] = 46
        out[:, p + 2 : p + 10] = mcols[:, 1:]
        out[:, p + 10] = 101
        out[:, p + 11] = np.where(e10 < 0, 45, 43)
        out[:, p + 12 : p + 14] = _int_columns(np.abs(e10), 2)
        out[:, -1] = _NL
    else:
        width = 2 * wi + 2
        out = np.full((m, width), 32, np.uint8)
        out[:, :wi] = _int_columns(src + 1, wi)
        out[:, wi + 1 : 2 * wi + 1] = _int_columns(d + 1, wi)
        out[:, -1] = _NL
    with open(path, "wb") as f:
        f.write(
            f"%%MatrixMarket matrix coordinate {kind} general\n".encode()
        )
        f.write(f"{c.n} {c.n} {c.m}\n".encode())
        f.write(out.tobytes())
