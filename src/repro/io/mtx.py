"""MTX → CSR loader (paper Algorithms 3–5, adapted per DESIGN.md §2).

The paper's loader wins by (a) block-partitioned parallel byte parsing,
(b) per-partition degree counting, (c) shifted-offset CSR fill with no
post-processing pass.  This container has one host core, so thread
parallelism becomes **byte-level vectorization**: the whole file is parsed
with a constant number of numpy passes (no per-line python).  The
partitioned degree counting and shifted-offset placement are kept
structurally (``num_partitions``), since they become the shard layout of
the distributed builder.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import csr as csr_mod

_NL = 10  # \n


@dataclasses.dataclass
class MtxHeader:
    symmetric: bool
    weighted: bool
    rows: int
    cols: int
    nnz: int
    header_end: int  # byte offset where data lines start


def read_header(buf: bytes) -> MtxHeader:
    """readHeader() of Alg 3."""
    pos = 0
    first = buf[: buf.index(b"\n")].decode()
    if not first.startswith("%%MatrixMarket"):
        raise ValueError("not an MTX file")
    toks = first.lower().split()
    weighted = "pattern" not in toks
    symmetric = "symmetric" in toks
    # skip comment lines
    while True:
        end = buf.index(b"\n", pos)
        line = buf[pos : end + 1]
        if not line.startswith(b"%"):
            break
        pos = end + 1
    dims = buf[pos : buf.index(b"\n", pos)].split()
    rows, cols, nnz = int(dims[0]), int(dims[1]), int(dims[2])
    header_end = buf.index(b"\n", pos) + 1
    return MtxHeader(symmetric, weighted, rows, cols, nnz, header_end)


def _parse_fields(data: np.ndarray, line_starts: np.ndarray, n_fields: int):
    """Vectorized field parser: fixed number of byte passes per field.

    ``data`` uint8 buffer, ``line_starts`` int64 offsets.  Parses up to
    ``n_fields`` whitespace-separated numbers per line (integers, or
    floats for the weight field).  The per-digit loop below is the
    vectorized analogue of the paper's parseWholeNumber(): each pass
    advances every line's cursor by one byte.
    """
    n = line_starts.shape[0]
    cur = line_starts.copy()
    out = []
    size = data.shape[0]
    for f in range(n_fields):
        # findNextDigit(): skip non-numeric bytes (spaces)
        for _ in range(4):  # tolerate a few separator bytes
            c = data[np.minimum(cur, size - 1)]
            isdig = (c >= 48) & (c <= 57) | (c == 45) | (c == 46)
            cur = np.where(~isdig & (cur < size), cur + 1, cur)
            if isdig.all():
                break
        neg = data[np.minimum(cur, size - 1)] == 45
        cur = np.where(neg, cur + 1, cur)
        if f < 2:
            val = np.zeros(n, np.int64)
            active = np.ones(n, bool)
            for _ in range(12):  # parseWholeNumber(): max digits of int32+
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                val = np.where(isdig, val * 10 + (c - 48), val)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            out.append(np.where(neg, -val, val))
        else:
            # parseFloat(): integer part
            ival = np.zeros(n, np.float64)
            active = np.ones(n, bool)
            for _ in range(12):
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                ival = np.where(isdig, ival * 10 + (c - 48), ival)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            # fractional part
            has_dot = data[np.minimum(cur, size - 1)] == 46
            cur = np.where(has_dot, cur + 1, cur)
            frac = np.zeros(n, np.float64)
            scale = np.ones(n, np.float64)
            active = has_dot.copy()
            for _ in range(9):
                c = data[np.minimum(cur, size - 1)]
                isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                frac = np.where(isdig, frac * 10 + (c - 48), frac)
                scale = np.where(isdig, scale * 10, scale)
                cur = np.where(isdig, cur + 1, cur)
                active &= isdig
                if not isdig.any():
                    break
            # exponent (rare; handle e/E with sign)
            has_e = np.isin(data[np.minimum(cur, size - 1)], (101, 69))
            if has_e.any():
                cur = np.where(has_e, cur + 1, cur)
                esign = data[np.minimum(cur, size - 1)] == 45
                cur = np.where(has_e & (esign | (data[np.minimum(cur, size - 1)] == 43)), cur + 1, cur)
                ev = np.zeros(n, np.int64)
                active = has_e.copy()
                for _ in range(3):
                    c = data[np.minimum(cur, size - 1)]
                    isdig = (c >= 48) & (c <= 57) & active & (cur < size)
                    ev = np.where(isdig, ev * 10 + (c - 48), ev)
                    cur = np.where(isdig, cur + 1, cur)
                    active &= isdig
                val = (ival + frac / scale) * np.power(
                    10.0, np.where(esign, -ev, ev)
                )
            else:
                val = ival + frac / scale
            out.append(np.where(neg, -val, val))
    return out


def parse_edgelist(buf: bytes, header: MtxHeader):
    """readEdgelist() of Alg 4, vectorized."""
    data = np.frombuffer(buf, dtype=np.uint8)
    body = data[header.header_end :]
    nl = np.flatnonzero(body == _NL)
    line_starts = np.concatenate([[0], nl + 1]).astype(np.int64)
    # drop empty trailing lines
    valid = line_starts < body.shape[0]
    line_starts = line_starts[valid]
    if line_starts.shape[0] > header.nnz:
        line_starts = line_starts[: header.nnz]
    n_fields = 3 if header.weighted else 2
    fields = _parse_fields(body, line_starts, n_fields)
    src = fields[0] - 1  # 1-based -> 0-based (Alg 4 line 20)
    dst = fields[1] - 1
    wgt = fields[2].astype(np.float32) if header.weighted else None
    if header.symmetric:
        # Alg 4 lines 28-33: add the reverse edge
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if wgt is not None:
            wgt = np.concatenate([wgt, wgt])
    return src, dst, wgt


def load_mtx(
    path_or_bytes, *, num_partitions: int = 4, sort: bool = True
) -> csr_mod.CSR:
    """loadGraph() of Alg 3: header -> edgelist -> partitioned CSR."""
    if isinstance(path_or_bytes, (str, bytes)):
        buf = (
            path_or_bytes
            if isinstance(path_or_bytes, bytes)
            else open(path_or_bytes, "rb").read()
        )
    else:
        buf = path_or_bytes.read()
    header = read_header(buf)
    src, dst, wgt = parse_edgelist(buf, header)
    n = max(header.rows, header.cols)
    return csr_mod.from_coo(
        src, dst, wgt, n=n, num_partitions=num_partitions, dedup=False, sort=sort
    )


def write_mtx(path: str, c: csr_mod.CSR, *, weighted: bool = True) -> None:
    """Round-trip writer (tests + benchmark input generation)."""
    o = np.asarray(c.offsets)
    d = np.asarray(c.dst)
    w = (
        np.asarray(c.wgt)
        if (c.wgt is not None and weighted)
        else np.ones(c.m, np.float32)
    )
    src = np.repeat(np.arange(c.n), np.diff(o))
    kind = "real" if weighted else "pattern"
    with open(path, "w") as f:
        f.write(f"%%MatrixMarket matrix coordinate {kind} general\n")
        f.write(f"{c.n} {c.n} {c.m}\n")
        if weighted:
            np.savetxt(
                f,
                np.column_stack([src + 1, d + 1, w]),
                fmt=("%d", "%d", "%.6g"),
            )
        else:
            np.savetxt(f, np.column_stack([src + 1, d + 1]), fmt="%d")
