"""Synthetic graph generators: RMAT (power-law, web-graph-like), uniform
(Erdős–Rényi-ish) and road-like low-degree graphs — covering the paper's
dataset families (web / social / road / k-mer) at container scale.
"""
from __future__ import annotations

import numpy as np

from ..core import csr as csr_mod
from ..core import edgebatch


def rmat_edges(
    rng: np.random.Generator,
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> tuple[np.ndarray, np.ndarray]:
    """RMAT generator (Graph500 parameters by default)."""
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities a,b,c,d
        src_bit = r >= a + b
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    return src, dst


def uniform_edges(
    rng: np.random.Generator, n: int, m: int
) -> tuple[np.ndarray, np.ndarray]:
    return (
        rng.integers(0, n, size=m, dtype=np.int64),
        rng.integers(0, n, size=m, dtype=np.int64),
    )


def road_like_edges(
    rng: np.random.Generator, n: int, avg_degree: float = 2.1
) -> tuple[np.ndarray, np.ndarray]:
    """Low-degree, high-diameter chain + shortcuts (asia_osm-style)."""
    chain_src = np.arange(n - 1, dtype=np.int64)
    chain_dst = chain_src + 1
    extra = int(n * max(avg_degree - 2.0, 0.05))
    esrc = rng.integers(0, n, size=extra, dtype=np.int64)
    off = rng.integers(1, 10, size=extra, dtype=np.int64)
    edst = np.minimum(esrc + off, n - 1)
    return (
        np.concatenate([chain_src, esrc]),
        np.concatenate([chain_dst, edst]),
    )


def make_graph(
    kind: str,
    *,
    scale: int = 10,
    edge_factor: int = 8,
    seed: int = 0,
    weighted: bool = True,
    symmetric: bool = True,
) -> csr_mod.CSR:
    """Named dataset families at container scale."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    if kind == "web":
        src, dst = rmat_edges(rng, scale, edge_factor, 0.57, 0.19, 0.19)
    elif kind == "social":
        src, dst = rmat_edges(rng, scale, edge_factor, 0.45, 0.25, 0.15)
    elif kind == "road":
        src, dst = road_like_edges(rng, n)
    elif kind == "uniform":
        src, dst = uniform_edges(rng, n, n * edge_factor)
    else:
        raise ValueError(f"unknown graph kind {kind!r}")
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    wgt = rng.uniform(0.5, 1.5, size=src.shape[0]).astype(np.float32) if weighted else None
    return csr_mod.from_coo(src, dst, wgt, n=n)


def update_batches(
    csr: csr_mod.CSR,
    *,
    fractions=(1e-4, 1e-3, 1e-2, 1e-1),
    seed: int = 1,
    kind: str = "insert",
):
    """Paper §4.2.3/4: random batches sized as fractions of |E|."""
    rng = np.random.default_rng(seed)
    out = []
    for f in fractions:
        count = max(int(round(csr.m * f)), 1)
        if kind == "insert":
            out.append((f, edgebatch.random_insertions(rng, csr.n, count)))
        else:
            out.append((f, edgebatch.random_deletions(rng, csr, count)))
    return out
